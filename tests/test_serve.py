"""Serving tests: engine generation, paged KV == contiguous, sampling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.configs.base import RunConfig, reduced
from repro.models import init_lm
from repro.serve import Request, ServeEngine
from repro.serve.kvcache import (PagePool, append_token, gather_kv,
                                 init_paged_kv, make_page_tables)
from repro.serve.serve_step import greedy_sample, temperature_sample

RCFG = RunConfig(kernels="xla", dtype="float32", remat=False)
KEY = jax.random.PRNGKey(0)


class TestEngine:
    def test_greedy_generation_deterministic(self):
        cfg = reduced(get("gemma2-2b"), n_layers=2, d_model=64, n_heads=2,
                      n_kv_heads=1, d_ff=128, vocab=128)
        params = init_lm(KEY, cfg)
        engine = ServeEngine(cfg, RCFG, params, max_len=64)
        prompts = [[1, 2, 3, 4, 5, 6, 7, 8]] * 2
        reqs = engine.generate(
            [Request(prompt=p, max_new_tokens=6) for p in prompts])
        assert all(len(r.output) == 6 for r in reqs)
        assert reqs[0].output == reqs[1].output  # same prompt ⇒ same output
        # regenerate: determinism
        reqs2 = engine.generate(
            [Request(prompt=p, max_new_tokens=6) for p in prompts])
        assert reqs2[0].output == reqs[0].output

    def test_sampling(self):
        logits = jnp.asarray([[0.0, 5.0, 1.0]])
        assert int(greedy_sample(logits)[0]) == 1
        t = temperature_sample(KEY, logits, temperature=1e-6)
        assert int(t[0]) == 1


class TestPagedKV:
    def test_paged_equals_contiguous(self):
        B, Hkv, dh, page, S = 2, 2, 16, 8, 64
        alloc = PagePool(n_pages=B * S // page + 4, page_size=page)
        tables = jnp.asarray(make_page_tables(alloc, B, S))
        pool = init_paged_kv(alloc.n_pages, page, Hkv, dh, jnp.float32)
        contiguous_k = np.zeros((B, Hkv, S, dh), np.float32)
        contiguous_v = np.zeros((B, Hkv, S, dh), np.float32)
        rng = np.random.default_rng(0)
        for pos in range(S):
            k = jnp.asarray(rng.standard_normal((B, Hkv, dh)), jnp.float32)
            v = jnp.asarray(rng.standard_normal((B, Hkv, dh)), jnp.float32)
            pool = append_token(pool, tables, jnp.int32(pos), k, v, page)
            contiguous_k[:, :, pos] = np.asarray(k)
            contiguous_v[:, :, pos] = np.asarray(v)
        gk, gv = gather_kv(pool, tables, S, page)
        np.testing.assert_allclose(np.asarray(gk), contiguous_k, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(gv), contiguous_v, rtol=1e-6)

    def test_pool_exhaustion(self):
        alloc = PagePool(n_pages=2, page_size=8)
        alloc.alloc()
        alloc.alloc()
        with pytest.raises(MemoryError):
            alloc.alloc()

    def test_release_recycles(self):
        alloc = PagePool(n_pages=2, page_size=8)
        p = alloc.alloc()
        alloc.release([p])
        assert alloc.alloc() == p


class TestInstream:
    def test_transforms(self):
        from repro.core import instream
        x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                        jnp.float32)
        assert instream.get("identity")(x) is x
        assert instream.get("cast")(x, jnp.bfloat16).dtype == jnp.bfloat16
        bt = instream.get("block_transpose")(x, block=(4, 4))
        assert bt.shape == x.shape
        # block transpose twice = identity
        bt2 = instream.get("block_transpose")(bt, block=(4, 4))
        np.testing.assert_allclose(np.asarray(bt2), np.asarray(x))

    def test_quantize_roundtrip(self):
        from repro.core.instream import dequantize_int8, quantize_int8
        x = jnp.asarray(np.random.default_rng(1).standard_normal(1000),
                        jnp.float32)
        q, s = quantize_int8(x)
        err = float(jnp.max(jnp.abs(dequantize_int8(q, s) - x)))
        assert err < float(s) * 0.51 + 1e-6

    def test_error_feedback_reduces_bias(self):
        from repro.core.instream import (ErrorFeedbackCompressor,
                                         dequantize_int8)
        comp = ErrorFeedbackCompressor()
        g = {"w": jnp.asarray(
            np.random.default_rng(2).standard_normal(512) * 0.01,
            jnp.float32)}
        res = comp.init(g)
        total_true = np.zeros(512, np.float32)
        total_sent = np.zeros(512, np.float32)
        for _ in range(20):
            qs, res = comp.compress(g, res)
            total_true += np.asarray(g["w"])
            total_sent += np.asarray(dequantize_int8(*qs["w"]))
        # accumulated compressed signal tracks the true sum (EF property)
        rel = np.abs(total_sent - total_true).max() / \
            np.abs(total_true).max()
        assert rel < 0.05, rel
