"""Engine + front-end + back-end functional tests: bytes actually move,
error-handler verbs behave (paper §2.3), Init patterns generate."""

import numpy as np
import pytest

from repro.core import (DescFrontend, ErrorPolicy, IDMAEngine, InitPattern,
                        InstFrontend, MemoryMap, NdTransfer, Protocol,
                        RegFrontend, TensorDim, Transfer1D, TransferError,
                        init_stream, plan_nd_copy, write_chain)
from repro.core.descriptor import BackendOptions


def make_engine(**kw):
    mem = MemoryMap.create({Protocol.AXI4: 1 << 16, Protocol.OBI: 1 << 16})
    return IDMAEngine(mem=mem, **kw), mem


def fill(mem, proto, n, seed=0):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, n, dtype=np.uint8)
    mem.spaces[proto][:n] = data
    return data


class TestFunctionalCopy:
    def test_1d_cross_protocol(self):
        eng, mem = make_engine()
        data = fill(mem, Protocol.AXI4, 4096)
        eng.submit(Transfer1D(0, 512, 4096, Protocol.AXI4, Protocol.OBI))
        assert np.array_equal(mem.spaces[Protocol.OBI][512:512 + 4096], data)

    def test_nd_strided(self):
        eng, mem = make_engine()
        data = fill(mem, Protocol.AXI4, 8192)
        # gather 4 rows of 64 B with src stride 256 into dense dst
        nd = NdTransfer(0, 0, 64, (TensorDim(256, 64, 4),),
                        Protocol.AXI4, Protocol.OBI)
        eng.submit(nd)
        want = np.concatenate([data[i * 256:i * 256 + 64] for i in range(4)])
        assert np.array_equal(mem.spaces[Protocol.OBI][:256], want)

    def test_multi_backend_distribution(self):
        eng, mem = make_engine(num_backends=4, backend_boundary=256)
        data = fill(mem, Protocol.AXI4, 4096)
        eng.submit(Transfer1D(0, 0, 4096, Protocol.AXI4, Protocol.OBI))
        assert np.array_equal(mem.spaces[Protocol.OBI][:4096], data)
        assert eng.stats.bursts >= 16


class TestInit:
    def test_constant(self):
        eng, mem = make_engine()
        opts = BackendOptions(init_pattern=InitPattern.CONSTANT,
                              init_value=0xAB)
        eng.submit(Transfer1D(0, 100, 256, Protocol.INIT, Protocol.OBI,
                              options=opts))
        assert np.all(mem.spaces[Protocol.OBI][100:356] == 0xAB)

    def test_incrementing(self):
        eng, mem = make_engine()
        opts = BackendOptions(init_pattern=InitPattern.INCREMENTING)
        eng.submit(Transfer1D(0, 0, 512, Protocol.INIT, Protocol.OBI,
                              options=opts))
        want = (np.arange(512) & 0xFF).astype(np.uint8)
        assert np.array_equal(mem.spaces[Protocol.OBI][:512], want)

    def test_prng_split_invariance(self):
        """Legalized/split Init transfers produce the same stream."""
        a = init_stream(InitPattern.PSEUDORANDOM, 7, 0, 1024)
        b = np.concatenate([
            init_stream(InitPattern.PSEUDORANDOM, 7, 0, 100),
            init_stream(InitPattern.PSEUDORANDOM, 7, 100, 924)])
        assert np.array_equal(a, b)


class TestErrorHandler:
    def test_replay_recovers(self):
        eng, mem = make_engine(error_policy=ErrorPolicy(action="replay"))
        data = fill(mem, Protocol.AXI4, 2048)
        eng.inject_fault(3)
        eng.submit(Transfer1D(0, 0, 2048, Protocol.AXI4, Protocol.OBI))
        assert np.array_equal(mem.spaces[Protocol.OBI][:2048], data)
        assert eng.stats.replays == 1 and eng.stats.errors == 1

    def test_abort_raises(self):
        eng, mem = make_engine(error_policy=ErrorPolicy(action="abort"))
        fill(mem, Protocol.AXI4, 2048)
        eng.inject_fault(0)
        with pytest.raises(TransferError):
            eng.submit(Transfer1D(0, 0, 2048, Protocol.AXI4, Protocol.OBI))

    def test_continue_skips_offender(self):
        eng, mem = make_engine(error_policy=ErrorPolicy(action="continue"))
        data = fill(mem, Protocol.AXI4, 2048)
        eng.inject_fault(0)
        eng.submit(Transfer1D(0, 0, 2048, Protocol.AXI4, Protocol.OBI))
        # first burst skipped, rest copied
        assert eng.stats.errors == 1
        assert not np.array_equal(mem.spaces[Protocol.OBI][:2048], data)
        assert np.array_equal(mem.spaces[Protocol.OBI][512:2048],
                              data[512:2048])


class TestFrontends:
    def test_reg_frontend_launch_by_read(self):
        eng, mem = make_engine()
        data = fill(mem, Protocol.AXI4, 1024)
        fe = RegFrontend(eng, 32, ndims=2)
        fe.configure(0, 0, 1024, src_protocol=Protocol.AXI4,
                     dst_protocol=Protocol.OBI)
        tid = fe.launch()
        assert tid == 1
        assert fe.read(fe.STATUS) == 1
        assert np.array_equal(mem.spaces[Protocol.OBI][:1024], data)
        with pytest.raises(PermissionError):
            fe.write(fe.STATUS, 0)

    def test_desc_frontend_chain(self):
        eng, mem = make_engine()
        data = fill(mem, Protocol.AXI4, 4096)
        spm = bytearray(1024)
        base = write_chain(spm, 0, [(0, 0, 1024), (1024, 1024, 1024),
                                    (2048, 2048, 2048)],
                           src_protocol=Protocol.AXI4,
                           dst_protocol=Protocol.OBI)
        fe = DescFrontend(eng, spm)
        ids = fe.doorbell(base)
        assert len(ids) == 3 and fe.fetches == 3
        assert np.array_equal(mem.spaces[Protocol.OBI][:4096], data)

    def test_inst_frontend_instruction_counts(self):
        """Paper: 1-D launch in 3 instructions, 2-D in at most 6."""
        eng, mem = make_engine()
        data = fill(mem, Protocol.AXI4, 512)
        fe = InstFrontend(eng)
        tid, n = fe.copy_1d(0, 0, 256)
        assert n == 3 and tid == 1
        _, n2 = fe.copy_2d(0, 1024, 64, 128, 64, 4)
        assert n2 <= 6


class TestTilePlan:
    def test_plan_respects_budget_and_alignment(self):
        plan = plan_nd_copy((1000, 3000), 4, n_buffers=2,
                            vmem_budget=2 << 20)
        assert plan.tile[0] % 8 == 0 and plan.tile[1] % 128 == 0
        assert plan.vmem_bytes <= 2 << 20
        assert plan.grid[0] * plan.tile[0] >= 1000
        assert plan.grid[1] * plan.tile[1] >= 3000
