"""Static sanitizer: hazard sweep, spec lint, plan audit, and the
opt-in engine / collective-fabric wiring.

The hazard matrix here is the ordering model's ground truth: every code
gets a minimal crafted program, and the FIFO-allowed / cross-protocol /
generator-source negative cases pin down what must *not* be flagged.
"""

import numpy as np
import pytest

from repro.core import (DescriptorBatch, ErrorPolicy, Protocol,
                        build_engine, make_fragmented_batch, preset)
from repro.core.descriptor import NdTransfer, Transfer1D
from repro.core.spec import (PRESETS, BackendSpec, ChannelSpec, EngineSpec,
                             IrqSpec)
from repro.sanitize import (CODES, Report, SanitizeError, Unit, audit_replay,
                            as_batch, channel_units, check_batch, check_phase,
                            check_spec, check_units, severity)


def rows(*triples, src_p=Protocol.AXI4, dst_p=Protocol.AXI4):
    """Build a batch from (src, dst, length) triples."""
    s, d, ln = (np.asarray(c, dtype=np.int64) for c in zip(*triples))
    return DescriptorBatch.from_arrays(s, d, ln, src_protocol=src_p,
                                       dst_protocol=dst_p)


def spec2ch(channels=2, name="t"):
    return EngineSpec(
        name=name,
        backend=BackendSpec(protocols=(Protocol.AXI4,)),
        channels=ChannelSpec(count=channels),
        mem_spaces=((Protocol.AXI4, 1 << 16),))


# --------------------------------------------------------------------------
# Hazard sweep: the classification matrix
# --------------------------------------------------------------------------

class TestSweepMatrix:
    def test_disjoint_rows_clean(self):
        r = check_batch(rows((0, 0x1000, 64), (0x100, 0x2000, 64)))
        assert r.clean and r.codes == () and r.checked_rows == 2

    def test_h001_read_after_write(self):
        # row 0 writes [0x1000,0x1040), row 1 reads it: the vectorized
        # batch path gives no intra-item ordering, so the read races
        r = check_batch(rows((0, 0x1000, 64), (0x1000, 0x3000, 64)))
        assert r.codes == ("H001",)
        d = r.select("H001")[0]
        assert d.window == (0x1000, 0x1040)
        assert d.a.op == "write" and d.b.op == "read"

    def test_h004_write_after_read(self):
        r = check_batch(rows((0x1000, 0x3000, 64), (0, 0x1000, 64)))
        assert r.codes == ("H004",)

    def test_h002_write_after_write(self):
        r = check_batch(rows((0, 0x1000, 64), (0x100, 0x1020, 64)))
        assert r.codes == ("H002",)
        assert r.select("H002")[0].window == (0x1020, 0x1040)

    def test_h005_self_overlap(self):
        r = check_batch(rows((0x1000, 0x1020, 64)))
        assert r.codes == ("H005",)

    def test_h003_cross_channel(self):
        units = [Unit(rows((0, 0x1000, 64)), channel=0, item=0),
                 Unit(rows((0x100, 0x1020, 64)), channel=1, item=1)]
        assert check_units(units).codes == ("H003",)

    def test_same_channel_fifo_allowed(self):
        # same engine, same channel, different queue items: FIFO drains
        # them in order — overlap is a legal dependence, not a hazard
        units = [Unit(rows((0, 0x1000, 64)), channel=0, item=0),
                 Unit(rows((0x100, 0x1020, 64)), channel=0, item=1)]
        assert check_units(units).clean

    def test_h006_cross_engine(self):
        r = check_phase([rows((0, 0x1000, 64)), rows((0x100, 0x1020, 64))])
        assert r.codes == ("H006",)
        # dict form (rank -> batch) is equivalent
        r2 = check_phase({0: rows((0, 0x1000, 64)),
                          1: rows((0x100, 0x1020, 64))})
        assert r2.codes == ("H006",)

    def test_cross_protocol_never_collides(self):
        units = [Unit(rows((0, 0x1000, 64), dst_p=Protocol.AXI4)),
                 Unit(rows((0, 0x1000, 64), dst_p=Protocol.OBI),
                      item=1)]
        assert check_units(units).clean

    def test_mixed_protocol_rows_within_one_batch(self):
        # per-row protocol columns force the sweep's flat fallback path
        b = DescriptorBatch.from_arrays(
            np.asarray([0, 0x100], np.int64),
            np.asarray([0x1000, 0x1020], np.int64),
            np.asarray([64, 64], np.int64),
            src_proto=np.asarray([2, 3], np.uint8),
            dst_proto=np.asarray([2, 2], np.uint8))
        assert "H002" in check_batch(b).codes

    def test_generator_source_has_no_read_interval(self):
        # INIT source "reading" the bytes another row writes is fine —
        # a pattern generator touches no memory
        b = DescriptorBatch.from_arrays(
            np.asarray([0x1000, 0], np.int64),
            np.asarray([0x1000, 0x3000], np.int64),
            np.asarray([64, 64], np.int64),
            src_protocol=Protocol.INIT, dst_protocol=Protocol.AXI4)
        assert check_batch(b).clean

    def test_zero_length_rows_ignored(self):
        assert check_batch(rows((0, 0x1000, 0), (0, 0x1000, 0))).clean

    def test_read_read_overlap_never_flagged(self):
        # a million broadcast reads of one buffer are legal; here two
        r = check_batch(rows((0x500, 0x1000, 64), (0x500, 0x2000, 64)))
        assert r.clean

    def test_touching_intervals_do_not_overlap(self):
        # half-open intervals: [0x1000,0x1040) then [0x1040,0x1080)
        assert check_batch(rows((0, 0x1000, 64), (0x100, 0x1040, 64))).clean


class TestSweepControls:
    def test_suppress_counts(self):
        r = check_batch(rows((0x1000, 0x1020, 64)), suppress=("H005",))
        assert r.clean and r.suppressed == {"H005": 1}

    def test_unknown_suppress_code_rejected(self):
        with pytest.raises(ValueError, match="unknown diagnostic code"):
            check_batch(rows((0, 0x1000, 64)), suppress=("H999",))

    def test_per_code_limit_with_note(self):
        # 6 rows all writing one address: C(6,2)=15 H002 pairs, limit 3
        b = rows(*[(i * 0x100, 0x1000, 64) for i in range(6)])
        r = check_batch(b, limit=3)
        assert len(r.select("H002")) == 3
        assert any("more than 3 instances" in n for n in r.notes)

    def test_budget_exhaustion_note(self):
        b = rows(*[(i * 0x100, 0x1000, 64) for i in range(8)])
        r = check_batch(b, budget=4)
        assert any("budget exhausted" in n for n in r.notes)

    def test_fragmented_batch_needs_h005_suppression(self):
        # §4.4 fragmented copy is a deliberate src==dst identity stream
        b = make_fragmented_batch(1 << 12, 67)
        assert check_batch(b).has("H005")
        r = check_batch(b, suppress=("H005",))
        assert r.clean and r.suppressed["H005"] == len(b)

    def test_report_format_and_merge(self):
        r = check_batch(rows((0, 0x1000, 64), (0x100, 0x1020, 64)))
        text = r.format()
        assert "HAZARDOUS" in text and "H002" in text
        total = Report()
        total.merge(r).merge(check_batch(rows((0, 0x7000, 64))))
        assert total.checked_rows == 3 and total.codes == ("H002",)

    def test_severity_model(self):
        assert severity("H003") == "error"
        assert severity("P001") == "error"
        assert severity("S002") == "warning"
        assert set(CODES) == {f"H00{i}" for i in range(1, 8)} | \
            {f"S00{i}" for i in range(1, 6)} | {"P001", "P002", "P003"}

    def test_warnings_keep_report_clean(self):
        spec = spec2ch()
        bad = EngineSpec(
            name="warn", backend=spec.backend,
            channels=ChannelSpec(count=1),
            irq=IrqSpec(vectors=4),
            mem_spaces=spec.mem_spaces)
        r = check_spec(bad)
        assert r.has("S004") and r.clean   # warnings never fail


class TestPayloadNormalization:
    def test_as_batch_transfer1d(self):
        b = as_batch(Transfer1D(src_addr=0, dst_addr=0x100, length=32))
        assert len(b) == 1 and int(b.length[0]) == 32

    def test_as_batch_nd(self):
        from repro.core.descriptor import TensorDim
        nd = NdTransfer(src_addr=0, dst_addr=0x1000, inner_length=64,
                        dims=(TensorDim(src_stride=256, dst_stride=64,
                                        reps=4),))
        b = as_batch(nd)
        assert len(b) == 4
        assert check_batch(b).clean

    def test_as_batch_rejects_unknown(self):
        with pytest.raises(TypeError, match="cannot sanitize"):
            as_batch(object())

    def test_channel_units_mirror_dispatch(self):
        # round-robin over 2 channels puts the overlapping rows on
        # different channels: exactly the engine's dispatch hazard
        b = rows((0, 0x1000, 64), (0x100, 0x1020, 64))
        units = channel_units(b, 2)
        assert [u.channel for u in units] == [0, 1]
        assert check_units(units).codes == ("H003",)


# --------------------------------------------------------------------------
# S-codes: spec misconfiguration lint
# --------------------------------------------------------------------------

class TestSpecCheck:
    def test_presets_all_clean(self):
        for name in PRESETS:
            r = check_spec(preset(name))
            assert not r.diagnostics, (name, r.codes)

    def test_s003_port_without_backing_space(self):
        spec = EngineSpec(
            name="s3",
            backend=BackendSpec(protocols=(Protocol.AXI4, Protocol.OBI)),
            mem_spaces=((Protocol.AXI4, 1 << 14),))
        assert check_spec(spec).has("S003")

    def test_s004_excess_irq_vectors(self):
        spec = EngineSpec(
            name="s4", backend=BackendSpec(protocols=(Protocol.AXI4,)),
            channels=ChannelSpec(count=2), irq=IrqSpec(vectors=5),
            mem_spaces=((Protocol.AXI4, 1 << 14),))
        assert check_spec(spec).has("S004")

    def test_s005_replay_with_zero_budget(self):
        spec = EngineSpec(
            name="s5",
            backend=BackendSpec(
                protocols=(Protocol.AXI4,),
                error_policy=ErrorPolicy(action="replay", max_replays=0)),
            mem_spaces=((Protocol.AXI4, 1 << 14),))
        assert check_spec(spec).has("S005")

    def test_s002_plan_cache_multiport(self):
        spec = EngineSpec(
            name="s2",
            backend=BackendSpec(protocols=(Protocol.AXI4,), num_ports=2,
                                boundary=4096),
            plan_cache=True,
            mem_spaces=((Protocol.AXI4, 1 << 14),))
        assert check_spec(spec).has("S002")


# --------------------------------------------------------------------------
# Engine wiring: sanitize= modes, drain check, plan audit
# --------------------------------------------------------------------------

def _submit_racy(engine):
    engine.submit_async(Transfer1D(src_addr=0x0000, dst_addr=0x8000,
                                   length=256))
    engine.submit_async(Transfer1D(src_addr=0x1000, dst_addr=0x8080,
                                   length=256))


class TestEngineWiring:
    def test_raise_mode_blocks_racy_drain(self):
        engine = build_engine(spec2ch(), sanitize=True)
        _submit_racy(engine)
        with pytest.raises(SanitizeError) as err:
            engine.wait_all()
        assert err.value.report.codes == ("H003",)
        assert len(engine.sanitize_reports) == 1

    def test_warn_mode_drains_anyway(self):
        engine = build_engine(spec2ch(), sanitize="warn")
        _submit_racy(engine)
        with pytest.warns(RuntimeWarning, match="H003"):
            engine.wait_all()
        assert not any(engine._queues)   # drained despite the finding

    def test_clean_program_certified_and_drained(self):
        engine = build_engine(spec2ch(), sanitize=True)
        engine.submit_async(Transfer1D(src_addr=0, dst_addr=0x8000,
                                       length=256))
        engine.submit_async(Transfer1D(src_addr=0x1000, dst_addr=0x9000,
                                       length=256))
        engine.wait_all()
        assert not any(engine._queues)
        assert len(engine.sanitize_reports) == 1
        assert engine.sanitize_reports[0].clean

    def test_off_by_default(self):
        engine = build_engine(spec2ch())
        _submit_racy(engine)
        engine.wait_all()    # no error: analysis is opt-in
        assert engine.sanitize_reports == []

    def test_invalid_mode_rejected(self):
        with pytest.raises(ValueError, match="sanitize must be"):
            build_engine(spec2ch(), sanitize="loud")

    def test_same_channel_pipeline_not_flagged(self):
        # FIFO dependence through one channel is legal on the engine too
        engine = build_engine(spec2ch(channels=1), sanitize=True)
        engine.submit_async(Transfer1D(src_addr=0, dst_addr=0x8000,
                                       length=64))
        engine.submit_async(Transfer1D(src_addr=0x8000, dst_addr=0x9000,
                                       length=64))
        engine.wait_all()
        assert engine.sanitize_reports[0].clean


class TestPlanAudit:
    def _engine(self):
        return build_engine(spec2ch(channels=1), plan_cache=True,
                            sanitize=True)

    def test_hit_is_audited_clean(self):
        engine = self._engine()
        engine.submit_async(Transfer1D(src_addr=0x0000, dst_addr=0x8000,
                                       length=300))
        engine.wait_all()
        # congruent mod 4096 (the signature's structure modulus) -> hit
        engine.submit_async(Transfer1D(src_addr=0x4000, dst_addr=0xC000,
                                       length=300))
        engine.wait_all()
        assert engine.plan_cache.stats.hits == 1
        audits = [r for r in engine.sanitize_reports if r.checked_rows == 1
                  and not r.diagnostics]
        assert audits, "expected a clean plan-audit report on the hit"

    def test_tampered_plan_flagged_p001(self):
        engine = self._engine()
        engine.submit_async(Transfer1D(src_addr=0x0000, dst_addr=0x8000,
                                       length=300))
        engine.wait_all()
        plan = next(iter(engine.plan_cache._plans.values()))
        plan.length = plan.length.copy()
        plan.length[0] += 8    # corrupt the frozen burst structure
        with pytest.raises(SanitizeError) as err:
            engine.submit_async(Transfer1D(src_addr=0x4000,
                                           dst_addr=0xC000, length=300))
            engine.wait_all()
        assert err.value.report.has("P001")

    def test_audit_replay_miss_returns_none(self):
        engine = self._engine()
        t = Transfer1D(src_addr=0, dst_addr=0x8000, length=300)
        assert audit_replay(engine.plan_cache, t,
                            bus_width=engine.bus_width) is None


# --------------------------------------------------------------------------
# Collective fabric phase certification
# --------------------------------------------------------------------------

class TestFabricCertification:
    def _fabric(self):
        from repro.dist.fabric import CollectiveFabric
        return CollectiveFabric(4, region_bytes=1 << 14, channels=2,
                                sanitize=True)

    def test_all_four_collectives_certified(self):
        x = np.arange(256, dtype=np.float32)
        shards = [x + r for r in range(4)]
        fab = self._fabric()
        out, _ = fab.allgather(shards)
        np.testing.assert_array_equal(out[0], np.stack(shards))
        fab2 = self._fabric()
        red, _ = fab2.allreduce(shards)
        np.testing.assert_allclose(red[0], sum(shards))
        fab3 = self._fabric()
        fab3.alltoall([np.stack([x + 10 * r + c for c in range(4)])
                       for r in range(4)])
        fab4 = self._fabric()
        base = [r * fab4.region_bytes for r in range(4)]
        fab4.transport([DescriptorBatch.from_arrays(
            np.asarray([b], np.int64), np.asarray([b + 4096], np.int64),
            np.asarray([2048], np.int64),
            src_protocol=fab4.proto, dst_protocol=fab4.proto)
            for b in base])
        for fab_i in (fab, fab2, fab3, fab4):
            assert fab_i.sanitize_reports
            for name, report in fab_i.sanitize_reports:
                assert report.clean, (name, report.codes)

    def test_corrupted_schedule_rejected(self):
        # every rank writes rank 0's bytes: a cross-engine race
        fab = self._fabric()
        batches = [DescriptorBatch.from_arrays(
            np.asarray([r * fab.region_bytes], np.int64),
            np.asarray([0x100], np.int64),
            np.asarray([512], np.int64),
            src_protocol=fab.proto, dst_protocol=fab.proto)
            for r in range(4)]
        with pytest.raises(SanitizeError) as err:
            fab.transport(batches)
        assert err.value.report.has("H006")


# --------------------------------------------------------------------------
# In-repo program corpus + CLI
# --------------------------------------------------------------------------

class TestCorpusAndCli:
    def test_kv_templates_certified(self):
        from repro.serve.kvcache import (KVLayout, append_descriptors,
                                         gather_descriptors)
        layout = KVLayout(n_pages=64, page_size=16, n_kv_heads=4,
                          head_dim=32)
        table = np.random.default_rng(0).permutation(64)[:32] \
            .reshape(8, 4).astype(np.int32)
        assert check_batch(gather_descriptors(layout, table,
                                              max_len=64)).clean
        assert check_batch(append_descriptors(layout, table, pos=17)).clean

    def test_cli_demo_corpus_fuzz(self, capsys):
        from repro.sanitize.__main__ import main
        assert main(["--demo"]) == 0
        assert main(["--corpus"]) == 0
        assert main(["--fuzz-racy", "6"]) == 0
        out = capsys.readouterr().out
        assert "H003" in out            # the demo prints its finding
        assert "0 hazardous" in out
        assert "6/6 flagged" in out

    def test_cli_no_args_prints_help(self, capsys):
        from repro.sanitize.__main__ import main
        assert main([]) == 0
        assert "--corpus" in capsys.readouterr().out
