"""Trainer + fault tolerance: replay/continue verbs, node-failure restore,
data-pipeline determinism, optimizer sanity."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.configs.base import RunConfig, reduced
from repro.data import SyntheticLMSource, make_pipeline
from repro.dist.fault import FaultConfig, FaultInjector
from repro.train import Trainer, TrainerConfig
from repro.optim import adamw_init, adamw_update

RCFG = RunConfig(kernels="xla", dtype="float32", remat=False,
                 learning_rate=1e-3)


def small_trainer(tmp_path=None, steps=6, injector=None, policy="replay",
                  arch="gemma2-2b", seed=0):
    cfg = reduced(get(arch), n_layers=2, d_model=64, n_heads=2,
                  n_kv_heads=1, d_ff=128, vocab=128)
    tcfg = TrainerConfig(
        total_steps=steps, checkpoint_every=2,
        checkpoint_dir=str(tmp_path) if tmp_path else None,
        seed=seed, fault=FaultConfig(policy=policy))
    return Trainer(cfg, RCFG, tcfg, seq_len=32, global_batch=4,
                   injector=injector)


class TestPipeline:
    def test_deterministic_and_seekable(self):
        src = SyntheticLMSource(1000, 16, 4, seed=3)
        b1 = src.batch(5)
        b2 = src.batch(5)
        assert np.array_equal(b1["tokens"], b2["tokens"])
        pf = make_pipeline(1000, 16, 4, seed=3)
        for _ in range(3):
            step, batch = next(pf)
        pf.seek(2)
        step2, batch2 = next(pf)
        assert step2 == 2 and step == 2
        assert np.array_equal(batch["tokens"], batch2["tokens"])

    def test_prefetch_lookahead(self):
        pf = make_pipeline(100, 8, 2, start_step=10)
        assert len(pf._queue) == pf.lookahead
        step, _ = next(pf)
        assert step == 10


class TestOptim:
    def test_adamw_reduces_toy_loss(self):
        w = {"w": jnp.asarray([2.0, -3.0])}
        st = adamw_init(w)

        def loss(p):
            return jnp.sum(p["w"] ** 2)

        for _ in range(50):
            g = jax.grad(loss)(w)
            w, st, _ = adamw_update(g, st, w, lr=0.1, weight_decay=0.0)
        assert float(loss(w)) < 0.2

    def test_grad_clip(self):
        w = {"w": jnp.ones(4)}
        st = adamw_init(w)
        g = {"w": jnp.full(4, 100.0)}
        _, _, m = adamw_update(g, st, w, lr=0.1, grad_clip=1.0)
        assert float(m["grad_norm"]) == pytest.approx(200.0)


class TestTrainerFaults:
    def test_loss_decreases_on_fixed_batch(self):
        """Overfit one batch: loss must drop (uniform-random stream data is
        already at ln(V), so the trainer loop test checks replay/faults and
        this one checks optimization)."""
        from repro.configs import get
        from repro.configs.base import reduced
        from repro.train.train_step import (init_train_state,
                                            make_train_step)
        cfg = reduced(get("gemma2-2b"), n_layers=2, d_model=64, n_heads=2,
                      n_kv_heads=1, d_ff=128, vocab=128)
        key = jax.random.PRNGKey(0)
        state = init_train_state(key, cfg)
        step = jax.jit(make_train_step(cfg, RCFG, total_steps=40))
        batch = {"tokens": jax.random.randint(key, (4, 32), 0, 128)}
        first = None
        for _ in range(12):
            state, m = step(state, batch)
            first = first if first is not None else float(m["loss"])
        assert float(m["loss"]) < first - 0.2

    def test_replay_is_exact(self):
        """A replayed step produces the same state as a fault-free run."""
        inj = FaultInjector(fail_steps=[2], kind="step")
        tr_f = small_trainer(steps=4, injector=inj)
        s_f = tr_f.run()
        tr_c = small_trainer(steps=4)
        s_c = tr_c.run()
        assert tr_f.stats.replays == 1
        for a, b in zip(jax.tree_util.tree_leaves(s_f["params"]),
                        jax.tree_util.tree_leaves(s_c["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_continue_skips(self):
        inj = FaultInjector(fail_steps=[1], kind="step")
        tr = small_trainer(steps=4, injector=inj, policy="continue")
        tr.run()
        assert tr.stats.skipped == 1

    def test_node_failure_restores_from_checkpoint(self, tmp_path):
        inj = FaultInjector(fail_steps=[4], kind="node")
        tr = small_trainer(tmp_path, steps=6, injector=inj)
        state = tr.run()
        assert tr.stats.node_failures == 1
        assert int(state["step"]) == 6
        # equivalent to an uninterrupted run (deterministic replay)
        tr2 = small_trainer(steps=6)
        s2 = tr2.run()
        for a, b in zip(jax.tree_util.tree_leaves(state["params"]),
                        jax.tree_util.tree_leaves(s2["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

    def test_restart_from_checkpoint_continues(self, tmp_path):
        tr = small_trainer(tmp_path, steps=4)
        tr.run()
        # "new process": fresh trainer picks up at step 4
        tr2 = small_trainer(tmp_path, steps=6)
        state = tr2.run()
        assert int(state["step"]) == 6


class TestMicrobatch:
    def test_grad_accumulation_matches_full_batch(self):
        from repro.train.train_step import init_train_state, make_train_step
        cfg = reduced(get("internlm2-20b"), n_layers=2, d_model=64,
                      n_heads=2, n_kv_heads=1, d_ff=128, vocab=128)
        key = jax.random.PRNGKey(0)
        state = init_train_state(key, cfg)
        batch = {"tokens": jax.random.randint(key, (4, 32), 0, 128)}
        s1, m1 = make_train_step(cfg, RCFG)(state, batch)
        rc2 = RunConfig(kernels="xla", dtype="float32", remat=False,
                        learning_rate=1e-3, microbatch=2)
        s2, m2 = make_train_step(cfg, rc2)(state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                        jax.tree_util.tree_leaves(s2["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-6)
