import importlib.util
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# Skip collecting test modules whose hard dependencies are not present in
# this build, instead of aborting the whole run at collection time.  The
# table is DATA so the skip set is auditable: `SKIP_REASONS` records WHY
# each module was dropped, `pytest_report_header` prints it at the top of
# every run, and tests/test_dep_skip_guard.py fails the suite if an entry
# here names a dependency that actually exists (a stale skip silently
# hiding real tests).
_DEP_SKIPS = {
    "hypothesis": ["test_legalizer.py", "test_midend.py",
                   "test_property_system.py"],
}


def _have(module: str) -> bool:
    try:
        return importlib.util.find_spec(module) is not None
    except ModuleNotFoundError:   # parent package itself not importable
        return False


collect_ignore = []
SKIP_REASONS = {}   # test module -> missing import name
for _dep, _modules in _DEP_SKIPS.items():
    if not _have(_dep):
        collect_ignore += _modules
        for _m in _modules:
            SKIP_REASONS[_m] = _dep


def pytest_report_header(config):
    if not SKIP_REASONS:
        return ["dep-skips: none (all optional deps present)"]
    return ["dep-skips: " + ", ".join(
        f"{m} (missing {dep!r})" for m, dep in sorted(SKIP_REASONS.items()))]


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600
                     ) -> str:
    """Run `code` in a subprocess with N fake host devices.

    Multi-device tests must not pollute this process's jax device state
    (smoke tests see 1 device), so they execute in a child interpreter.
    Raises on failure with combined output.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", ""))
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_with_devices
