"""Guard against stale dep-skips: every module conftest.py drops at
collection time must be dropped for a dependency that is ACTUALLY
missing.  The failure mode this catches: a package gets added to the
image (or a subsystem lands in-repo) but its tests silently stay
skipped because nobody revisits the skip table."""

import os

import conftest


def test_skip_table_modules_exist():
    """Every module named in the skip table is a real test file — a
    renamed test must not leave a dangling skip entry behind."""
    here = os.path.dirname(os.path.abspath(__file__))
    for modules in conftest._DEP_SKIPS.values():
        for m in modules:
            assert os.path.exists(os.path.join(here, m)), \
                f"skip table names {m}, which does not exist"


def test_no_stale_dep_skips():
    """A module may only be skipped while its dependency is missing.  If
    this fails, the named import now resolves: delete the skip-table
    entry (or fix the test module) so those tests run again."""
    stale = {m: dep for m, dep in conftest.SKIP_REASONS.items()
             if conftest._have(dep)}
    assert not stale, (
        f"stale dep-skips — these deps now import fine but their test "
        f"modules are still being dropped: {stale}")


def test_skip_reasons_match_ignores():
    """Every collection-time ignore has a recorded reason (the pytest
    header must account for every dropped module)."""
    ignored = set(conftest.collect_ignore)
    explained = set(conftest.SKIP_REASONS)
    assert ignored == explained, (
        f"unexplained ignores: {ignored - explained}; "
        f"reasons without ignores: {explained - ignored}")
