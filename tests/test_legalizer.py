"""Legalizer unit + property tests (paper Fig. 4 invariants)."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (PAGE_SIZE, BackendOptions, Protocol, Transfer1D,
                        check_legal, contiguous_coverage, legal_latency,
                        legalize, legalize_tile, total_bytes)

PROTOS = [Protocol.AXI4, Protocol.AXI_LITE, Protocol.AXI_STREAM,
          Protocol.OBI, Protocol.TILELINK]


def mk(src, dst, length, sp=Protocol.AXI4, dp=Protocol.AXI4, **opts):
    return Transfer1D(src, dst, length, sp, dp,
                      options=BackendOptions(**opts) if opts
                      else BackendOptions())


class TestAxi:
    def test_page_boundary_never_crossed(self):
        t = mk(PAGE_SIZE - 100, 0, 400)
        bursts = legalize(t, bus_width=8)
        check_legal(bursts, 8)
        assert len(bursts) >= 2

    def test_burst_cap_256_beats(self):
        t = mk(0, 0, 64 * 1024)
        bursts = legalize(t, bus_width=8)
        assert all(b.length <= 256 * 8 for b in bursts)

    def test_dst_page_rule_also_applies(self):
        t = mk(0, PAGE_SIZE - 64, 256)
        bursts = legalize(t, bus_width=8)
        check_legal(bursts, 8)

    def test_user_burst_cap(self):
        t = mk(0, 0, 4096, max_burst=64)
        bursts = legalize(t, bus_width=8)
        assert all(b.length <= 64 for b in bursts)


class TestNoBurstProtocols:
    @pytest.mark.parametrize("proto", [Protocol.OBI, Protocol.AXI_LITE])
    def test_single_beats(self, proto):
        t = mk(0, 0, 64, sp=proto, dp=proto)
        bursts = legalize(t, bus_width=4)
        assert all(b.length <= 4 for b in bursts)
        assert len(bursts) == 16


class TestTileLink:
    def test_pow2_naturally_aligned(self):
        t = mk(12, 12, 1000, sp=Protocol.TILELINK, dp=Protocol.TILELINK)
        bursts = legalize(t, bus_width=8)
        check_legal(bursts, 8)
        for b in bursts:
            assert b.length & (b.length - 1) == 0


class TestZeroLength:
    def test_zero_length_dropped(self):
        assert legalize(mk(0, 0, 0)) == []


@settings(max_examples=200, deadline=None)
@given(
    src=st.integers(0, 1 << 20),
    dst=st.integers(0, 1 << 20),
    length=st.integers(1, 64 * 1024),
    sp=st.sampled_from(PROTOS),
    dp=st.sampled_from(PROTOS),
    bus=st.sampled_from([4, 8, 16, 64]),
)
def test_legalize_properties(src, dst, length, sp, dp, bus):
    """For any transfer: bursts are legal, cover the exact byte range in
    order, and preserve total length."""
    t = Transfer1D(src, dst, length, sp, dp)
    bursts = legalize(t, bus_width=bus)
    check_legal(bursts, bus)
    assert total_bytes(bursts) == length
    assert contiguous_coverage(bursts)
    assert bursts[0].src_addr == src and bursts[0].dst_addr == dst
    assert bursts[-1].src_end == src + length


@settings(max_examples=100, deadline=None)
@given(rows=st.integers(1, 5000), cols=st.integers(1, 5000),
       itemsize=st.sampled_from([1, 2, 4]))
def test_tile_legalization(rows, cols, itemsize):
    tr, tc = legalize_tile((rows, cols), itemsize)
    from repro.core.legalizer import TPU_SUBLANES
    assert tr % TPU_SUBLANES[itemsize] == 0
    assert tc % 128 == 0
    assert tr * tc * itemsize <= 64 * 1024 * 1024


def test_latency_rule():
    assert legal_latency(0) == 2
    assert legal_latency(0, has_legalizer=False) == 1
    assert legal_latency(1) == 3
    assert legal_latency(2) == 4
    assert legal_latency(1, tensor_nd_zero_latency=True) == 2
