"""Mid-end tests: tensor_nd / mp_split / mp_dist / rt_3D (paper §2.2)."""

from hypothesis import given, settings, strategies as st

from repro.core import (NdTransfer, RtConfig, TensorDim, Transfer1D, mp_dist,
                        mp_dist_tree, mp_split, rt_schedule,
                        split_and_distribute, tensor_nd, total_bytes)
from repro.core.midend import no_boundary_crossing, preserves_bytes


class TestTensorNd:
    def test_dense_collapses_to_one(self):
        nd = NdTransfer(0, 0, 64, (TensorDim(64, 64, 4),
                                   TensorDim(256, 256, 8)))
        out = tensor_nd(nd)
        assert len(out) == 1 and out[0].length == 64 * 4 * 8

    def test_strided_walk_order_and_addresses(self):
        nd = NdTransfer(100, 200, 16, (TensorDim(32, 16, 3),))
        out = tensor_nd(nd)
        assert [t.src_addr for t in out] == [100, 132, 164]
        assert [t.dst_addr for t in out] == [200, 216, 232]

    def test_3d(self):
        nd = NdTransfer(0, 0, 8, (TensorDim(16, 8, 2),
                                  TensorDim(64, 16, 3)))
        out = tensor_nd(nd)
        assert len(out) == 6
        assert total_bytes(out) == 8 * 2 * 3


@settings(max_examples=150, deadline=None)
@given(
    inner=st.integers(1, 512),
    dims=st.lists(
        st.tuples(st.integers(1, 2048), st.integers(1, 2048),
                  st.integers(1, 6)),
        min_size=0, max_size=3),
)
def test_tensor_nd_preserves_bytes(inner, dims):
    tdims = tuple(TensorDim(max(s1, inner), max(s2, inner), r)
                  for s1, s2, r in dims)
    nd = NdTransfer(0, 0, inner, tdims)
    out = tensor_nd(nd)
    assert preserves_bytes(nd, out)


@settings(max_examples=150, deadline=None)
@given(
    src=st.integers(0, 1 << 16),
    dst=st.integers(0, 1 << 16),
    length=st.integers(1, 1 << 16),
    boundary=st.sampled_from([64, 256, 1024, 4096]),
    which=st.sampled_from(["src", "dst", "both"]),
)
def test_mp_split_properties(src, dst, length, boundary, which):
    t = Transfer1D(src, dst, length)
    out = mp_split(t, boundary, which=which)
    assert total_bytes(out) == length
    if which in ("dst", "both"):
        assert no_boundary_crossing(out, boundary, "dst")
    if which in ("src", "both"):
        assert no_boundary_crossing(out, boundary, "src")


class TestMpDist:
    def test_address_scheme_exclusive_regions(self):
        t = Transfer1D(0, 0, 4096)
        ports = split_and_distribute(t, 4, 256)
        for i, port in enumerate(ports):
            for b in port:
                assert (b.dst_addr // 256) % 4 == i

    def test_tree_matches_flat(self):
        t = Transfer1D(0, 128, 8192)
        split = mp_split(t, 512, which="dst")
        flat = mp_dist(split, 4, scheme="address", boundary=512)
        tree = mp_dist_tree(split, 4, boundary=512)
        assert flat == tree

    def test_round_robin(self):
        ts = [Transfer1D(i * 64, i * 64, 64) for i in range(10)]
        ports = mp_dist(ts, 3, scheme="round_robin")
        assert [len(p) for p in ports] == [4, 3, 3]


def test_rt_schedule_periodicity():
    nd = NdTransfer(0, 0, 64, (TensorDim(128, 64, 4),))
    sched = rt_schedule(RtConfig(period=100, num_launches=5), nd,
                        horizon=1000)
    assert [t for t, _ in sched] == [0, 100, 200, 300, 400]
    unbounded = rt_schedule(RtConfig(period=250), nd, horizon=1000)
    assert len(unbounded) == 4
