"""Multi-channel concurrent engine model + async submission control plane.

Property tests (seeded random streams, no hypothesis dependency):

* `simulate_channels` with one channel is cycle-identical to
  `simulate_batch` — the shared-endpoint terms must collapse exactly onto
  the single-channel recurrences;
* total bytes moved are channel-count-invariant for an even split;
* concurrency scales aggregate bandwidth on a high-latency endpoint and
  a shared `outstanding` credit window correctly caps it.
"""

import numpy as np
import pytest

from repro.core import (HBM, SRAM, DescriptorBatch, EngineConfig,
                        ErrorPolicy, IDMAEngine, MemSystem, MemoryMap,
                        Protocol, Transfer1D, TransferError,
                        make_fragmented_batch, simulate_batch,
                        simulate_channels, write_chain)
from repro.core.frontend import DescFrontend


def random_batch(rng, n, window=1 << 20, max_len=300) -> DescriptorBatch:
    return DescriptorBatch.from_arrays(
        src_addr=rng.integers(0, window, n),
        dst_addr=rng.integers(0, window, n),
        length=rng.integers(0, max_len, n))


CONFIGS = [
    EngineConfig(bus_width=4),
    EngineConfig(bus_width=8, n_outstanding=8),
    EngineConfig(bus_width=4, decoupled=False),
    EngineConfig(bus_width=4, buffer_beats=4),
    EngineConfig(bus_width=8, config_cycles=5, exclusive_transfers=True),
    EngineConfig(bus_width=4, num_midends=1),
]


class TestSingleChannelEquivalence:
    @pytest.mark.parametrize("cfg", CONFIGS)
    def test_random_streams_match_simulate_batch(self, cfg):
        rng = np.random.default_rng(hash(cfg.bus_width + cfg.config_cycles)
                                    % (1 << 32))
        for trial in range(8):
            batch = random_batch(np.random.default_rng(trial), 64)
            ref = simulate_batch(batch, cfg, HBM, SRAM)
            got = simulate_channels([batch], cfg, (HBM, SRAM)).per_channel[0]
            assert got.cycles == ref.cycles
            assert got.bus_beats == ref.bus_beats
            assert got.first_read_req == ref.first_read_req
            assert got.n_bursts == ref.n_bursts
            assert got.useful_bytes == ref.useful_bytes

    def test_same_endpoint_object_both_roles(self):
        """src is dst (fragmented copy): read/write accounting must still
        match the single-channel model exactly."""
        cfg = EngineConfig(bus_width=4, n_outstanding=4)
        for frag in (1, 7, 16, 64):
            batch = make_fragmented_batch(4096, frag)
            ref = simulate_batch(batch, cfg, HBM, HBM)
            got = simulate_channels([batch], cfg, (HBM, HBM)).per_channel[0]
            assert got.cycles == ref.cycles

    def test_contention_period_shared_accounting(self):
        mem = MemSystem("L2", latency=8, outstanding=8, contention_period=16)
        cfg = EngineConfig(bus_width=8)
        batch = make_fragmented_batch(8192, 64)
        ref = simulate_batch(batch, cfg, mem, mem)
        got = simulate_channels([batch], cfg, (mem, mem)).per_channel[0]
        assert got.cycles == ref.cycles

    def test_empty_channel(self):
        res = simulate_channels([DescriptorBatch.empty()],
                                EngineConfig(bus_width=4), (SRAM, SRAM))
        assert res.aggregate.cycles == 0
        assert res.aggregate.useful_bytes == 0


class TestChannelInvariants:
    def test_total_bytes_channel_count_invariant(self):
        cfg = EngineConfig(bus_width=4, n_outstanding=2)
        total = 32 * 1024
        for n in (1, 2, 4, 8):
            batches = [make_fragmented_batch(total // n, 16)
                       for _ in range(n)]
            res = simulate_channels(batches, cfg, (HBM, HBM))
            assert res.aggregate.useful_bytes == total
            assert sum(r.useful_bytes for r in res.per_channel) == total
            assert res.aggregate.n_bursts == \
                sum(r.n_bursts for r in res.per_channel)

    def test_aggregate_cycles_is_makespan(self):
        cfg = EngineConfig(bus_width=4)
        batches = [make_fragmented_batch(1024, 16),
                   make_fragmented_batch(8192, 16)]
        res = simulate_channels(batches, cfg, (HBM, HBM))
        assert res.aggregate.cycles == max(r.cycles
                                           for r in res.per_channel)

    def test_hbm_concurrency_scales(self):
        """4 channels vs 1 on a shared deep endpoint: >= 1.5x aggregate
        throughput (the PR's acceptance bar; measured ~4x)."""
        cfg = EngineConfig(bus_width=4, n_outstanding=2)
        total = 64 * 1024
        bw = {}
        for n in (1, 4):
            batches = [make_fragmented_batch(total // n, 16)
                       for _ in range(n)]
            bw[n] = simulate_channels(batches, cfg,
                                      (HBM, HBM)).aggregate_bandwidth
        assert bw[4] / bw[1] >= 1.5

    def test_shared_outstanding_caps_scaling(self):
        """A shared credit window of 2 cannot scale with channel count."""
        tight = MemSystem("tight", latency=100, outstanding=2)
        cfg = EngineConfig(bus_width=4, n_outstanding=2)
        total = 64 * 1024
        bw = {}
        for n in (1, 4):
            batches = [make_fragmented_batch(total // n, 16)
                       for _ in range(n)]
            bw[n] = simulate_channels(batches, cfg,
                                      (tight, tight)).aggregate_bandwidth
        assert bw[4] / bw[1] <= 1.2

    def test_distinct_endpoints_do_not_contend(self):
        """Two channels on two *distinct* (but identical-parameter)
        endpoints run as fast per-channel as one channel alone."""
        cfg = EngineConfig(bus_width=4, n_outstanding=2)
        batch = make_fragmented_batch(8192, 16)
        solo = simulate_channels([batch], cfg, (HBM, HBM)).aggregate.cycles
        h2a = MemSystem("HBM-a", latency=100, outstanding=64)
        h2b = MemSystem("HBM-b", latency=100, outstanding=64)
        duo = simulate_channels(
            [batch, batch], cfg,
            [(h2a, h2a), (h2b, h2b)])
        assert duo.aggregate.cycles == solo

    def test_per_channel_config_list(self):
        cfg_fast = EngineConfig(bus_width=4, n_outstanding=16)
        cfg_slow = EngineConfig(bus_width=4, n_outstanding=1)
        batch = make_fragmented_batch(4096, 16)
        res = simulate_channels([batch, batch], [cfg_fast, cfg_slow],
                                (SRAM, SRAM))
        assert len(res.per_channel) == 2
        with pytest.raises(ValueError):
            simulate_channels([batch], [cfg_fast, cfg_slow], (SRAM, SRAM))


def make_engine(**kw):
    mem = MemoryMap.create({Protocol.AXI4: 1 << 16, Protocol.OBI: 1 << 16})
    return IDMAEngine(mem=mem, **kw), mem


def fill(mem, proto, n, seed=0):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, n, dtype=np.uint8)
    mem.spaces[proto][:n] = data
    return data


class TestAsyncSubmission:
    def test_submit_async_poll_wait_all(self):
        eng, mem = make_engine(num_channels=4)
        data = fill(mem, Protocol.AXI4, 4096)
        tids = [eng.submit_async(Transfer1D(i * 512, i * 512, 512,
                                            Protocol.AXI4, Protocol.OBI))
                for i in range(8)]
        assert all(eng.poll(t) == "pending" for t in tids)
        assert not np.any(mem.spaces[Protocol.OBI][:4096])  # nothing moved
        res = eng.wait_all()
        assert all(eng.poll(t) == "done" for t in tids)
        assert np.array_equal(mem.spaces[Protocol.OBI][:4096], data)
        assert len(res.per_channel) == 4
        assert res.aggregate.useful_bytes == 4096
        # round-robin: every channel got two descriptors
        assert [r.n_bursts > 0 for r in res.per_channel] == [True] * 4

    def test_sync_submit_is_adapter(self):
        eng, mem = make_engine(num_channels=2)
        data = fill(mem, Protocol.AXI4, 1024)
        tid = eng.submit(Transfer1D(0, 0, 1024, Protocol.AXI4, Protocol.OBI))
        assert eng.poll(tid) == "done"
        assert eng.last_completed_id() == tid
        assert np.array_equal(mem.spaces[Protocol.OBI][:1024], data)

    def test_dispatch_batch_shards_across_channels(self):
        eng, mem = make_engine(num_channels=4)
        data = fill(mem, Protocol.AXI4, 4096)
        batch = DescriptorBatch.from_arrays(
            src_addr=np.arange(16, dtype=np.int64) * 256,
            dst_addr=np.arange(16, dtype=np.int64) * 256,
            length=256, src_protocol=Protocol.AXI4,
            dst_protocol=Protocol.OBI)
        ids = eng.dispatch_batch(batch)
        assert len(ids) == 16 and eng.poll(ids[7]) == "pending"
        res = eng.wait_all()
        assert np.array_equal(mem.spaces[Protocol.OBI][:4096], data)
        assert all(eng.poll(t) == "done" for t in ids)
        assert all(r.n_bursts > 0 for r in res.per_channel)
        # the single completion record accumulates over all four shards
        rec = eng._record_for(ids[0])
        assert rec.count == 16 and rec.bytes_moved == 4096
        assert rec.pending == 0

    def test_poll_unknown_tid_raises(self):
        eng, _ = make_engine()
        with pytest.raises(KeyError):
            eng.poll(999)

    def test_wait_all_empty_is_noop(self):
        eng, _ = make_engine(num_channels=2)
        res = eng.wait_all()
        assert res.aggregate.cycles == 0 and res.per_channel == []

    def test_abort_marks_record_and_keeps_rest_queued(self):
        eng, mem = make_engine(num_channels=2,
                               error_policy=ErrorPolicy(action="abort"))
        data = fill(mem, Protocol.AXI4, 2048)
        t1 = eng.submit_async(Transfer1D(0, 0, 1024,
                                         Protocol.AXI4, Protocol.OBI))
        t2 = eng.submit_async(Transfer1D(1024, 1024, 1024,
                                         Protocol.AXI4, Protocol.OBI))
        eng.inject_fault(0)
        with pytest.raises(TransferError):
            eng.wait_all()
        assert eng.poll(t1) == "error"
        assert eng.poll(t2) == "pending"      # still queued
        eng.inject_fault(None)
        eng.wait_all()
        assert eng.poll(t2) == "done"
        assert np.array_equal(mem.spaces[Protocol.OBI][1024:2048],
                              data[1024:2048])

    def test_channel_pinning_and_range_check(self):
        eng, _ = make_engine(num_channels=2)
        eng.submit_async(Transfer1D(0, 0, 64, Protocol.AXI4, Protocol.OBI),
                         channel=1)
        assert len(eng._queues[1]) == 1 and not eng._queues[0]
        with pytest.raises(ValueError):
            eng.submit_async(Transfer1D(0, 0, 64), channel=5)
        eng.wait_all()

    def test_doorbell_async_and_ring_dispatch(self):
        eng, mem = make_engine(num_channels=2)
        data = fill(mem, Protocol.AXI4, 2048)
        spm = bytearray(512)
        base = write_chain(spm, 0, [(0, 0, 1024), (1024, 1024, 1024)],
                           src_protocol=Protocol.AXI4,
                           dst_protocol=Protocol.OBI)
        fe = DescFrontend(eng, spm)
        ids = fe.doorbell_async(base)
        assert all(eng.poll(t) == "pending" for t in ids)
        ids2 = fe.doorbell_ring(0, 2, async_submit=True)
        eng.wait_all()
        assert all(eng.poll(t) == "done" for t in ids + ids2)
        assert np.array_equal(mem.spaces[Protocol.OBI][:2048], data)

    def test_timing_only_engine_wait_all(self):
        """mem=None engines still produce the multi-channel timing result."""
        eng = IDMAEngine(num_channels=2)
        for i in range(4):
            eng.submit_async(Transfer1D(i * 64, i * 64, 64))
        res = eng.wait_all()
        assert res.aggregate.useful_bytes == 256
        assert eng.stats.completed == 4
