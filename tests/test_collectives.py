"""Descriptor-lowered collectives vs plain NumPy references.

Byte identity is the contract: the fabric's allreduce/allgather/
all-to-all — real `DescriptorBatch` traffic through N engines on one
contended `MemSystem` — must produce bit-for-bit the bytes of the
pure-NumPy schedule mirrors, for every engine count, dtype, and
non-power-of-two message size.  Plus: a 1-engine fabric transport is
cycle-identical to `simulate_batch` (the fabric adds orchestration, not
timing), and interrupt-driven completion is what advances phases.
"""

import numpy as np
import pytest

from repro.core import simulator as sim
from repro.core.backend import FaultSite
from repro.core.descriptor import DescriptorBatch, Protocol, concat_batches
from repro.core.engine import ErrorPolicy
from repro.dist.collectives import (CollectiveFabric, allreduce_cycles,
                                    fabric_spec, numpy_allgather,
                                    numpy_alltoall, numpy_halving_allreduce,
                                    numpy_ring_allreduce)

WORLDS = (1, 2, 4)
DTYPES = (np.float32, np.float64, np.int32, np.int64, np.uint8, np.float16)
# deliberately awkward sizes: 1 element, non-power-of-two, not divisible
# by any engine count, plus one "big" size
SIZES = (1, 7, 97, 1000, 4093)


def shards_for(world, nelems, dtype, seed=0):
    rng = np.random.default_rng(seed)
    if np.issubdtype(dtype, np.floating):
        return [rng.standard_normal(nelems).astype(dtype)
                for _ in range(world)]
    info = np.iinfo(dtype)
    hi = min(int(info.max), 100)
    return [rng.integers(0, hi, nelems).astype(dtype) for _ in range(world)]


def fabric(world, **kw):
    kw.setdefault("region_bytes", 1 << 18)
    return CollectiveFabric(world, **kw)


class TestByteIdentity:
    @pytest.mark.parametrize("world", WORLDS)
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("nelems", SIZES)
    def test_ring_allreduce(self, world, dtype, nelems):
        shards = shards_for(world, nelems, dtype)
        out, _ = fabric(world).allreduce(shards, algo="ring")
        ref = numpy_ring_allreduce(shards)
        assert len(out) == world
        for a, b in zip(out, ref):
            assert a.dtype == b.dtype and a.shape == b.shape
            assert a.tobytes() == b.tobytes()

    @pytest.mark.parametrize("world", WORLDS)
    @pytest.mark.parametrize("dtype", (np.float32, np.int64))
    @pytest.mark.parametrize("nelems", SIZES)
    def test_halving_allreduce(self, world, dtype, nelems):
        shards = shards_for(world, nelems, dtype)
        out, _ = fabric(world).allreduce(shards, algo="halving")
        ref = numpy_halving_allreduce(shards)
        for a, b in zip(out, ref):
            assert a.tobytes() == b.tobytes()

    @pytest.mark.parametrize("world", WORLDS)
    @pytest.mark.parametrize("dtype", (np.float32, np.uint8))
    @pytest.mark.parametrize("nelems", (1, 97, 1000))
    def test_allgather(self, world, dtype, nelems):
        shards = shards_for(world, nelems, dtype)
        out, _ = fabric(world).allgather(shards)
        ref = numpy_allgather(shards)
        for a, b in zip(out, ref):
            assert a.shape == (world, nelems)
            assert a.tobytes() == b.tobytes()

    @pytest.mark.parametrize("world", WORLDS)
    @pytest.mark.parametrize("dtype", (np.float32, np.int32))
    @pytest.mark.parametrize("nelems", (1, 97, 1000, 4093))
    def test_alltoall(self, world, dtype, nelems):
        shards = shards_for(world, nelems, dtype)
        out, _ = fabric(world).alltoall(shards)
        ref = numpy_alltoall(shards)
        for a, b in zip(out, ref):
            assert a.tobytes() == b.tobytes()

    def test_exact_dtypes_equal_plain_sum(self):
        """For associative dtypes the schedule order is invisible: the
        ring result IS the plain sum."""
        shards = shards_for(4, 1000, np.int64)
        out, _ = fabric(4).allreduce(shards)
        np.testing.assert_array_equal(out[0], np.sum(shards, axis=0))

    def test_float_close_to_plain_sum(self):
        shards = shards_for(4, 1000, np.float32)
        out, _ = fabric(4).allreduce(shards)
        np.testing.assert_allclose(out[0], np.sum(shards, axis=0),
                                   rtol=1e-4, atol=1e-5)

    def test_2d_shapes_roundtrip(self):
        shards = [np.arange(60, dtype=np.float32).reshape(5, 12) + r
                  for r in range(4)]
        out, _ = fabric(4).allreduce(shards)
        ref = numpy_ring_allreduce(shards)
        for a, b in zip(out, ref):
            assert a.shape == (5, 12)
            assert a.tobytes() == b.tobytes()


class TestCycleParity:
    def test_one_engine_transport_matches_simulate_batch(self):
        """World-1 transport: the fabric adds interrupt plumbing and a
        schedule around the same lowering + timing — cycles must be
        IDENTICAL to a bare `simulate_batch` of the legalized batch."""
        fab = fabric(1)
        batch = DescriptorBatch.from_arrays(
            np.array([0, 4096, 300, 9000]),
            np.array([16384, 20480, 24576, 28672]),
            np.array([1024, 777, 4096, 63]),
            src_protocol=Protocol.HBM, dst_protocol=Protocol.HBM)
        trace = fab.transport([batch])
        eng = fab.engines[0]
        lps = [lp for lp in eng._lower_ports(batch) if len(lp.batch)]
        cat = concat_batches([lp.batch for lp in lps])
        beats = (lps[0].beats if len(lps) == 1 else
                 np.concatenate([lp.beats for lp in lps]))
        ref = sim.simulate_batch(cat, fab.spec.effective_sim_config,
                                 fab.spec.src_system, fab.spec.dst_system,
                                 already_legal=True, beats=beats)
        assert trace.total_cycles == int(ref.cycles)

    def test_multi_engine_no_slower_than_per_phase_serial(self):
        """Contended parallel phases can never beat the serial replay of
        the same streams, and the speedup must be real at scale."""
        shards = shards_for(4, 1 << 14, np.float32)
        fab = fabric(4, region_bytes=1 << 18)
        _, trace = fab.allreduce(shards)
        serial = fab.serial_cycles(trace)
        assert trace.total_cycles <= serial
        assert serial / trace.total_cycles > 1.3


class TestFaultsAndCache:
    def test_transient_fault_replay_preserves_bytes(self):
        shards = shards_for(4, 500, np.int32)
        sites = {1: [FaultSite(index=2, kind="transient")],
                 3: [FaultSite(index=0, kind="stall", stall_cycles=64)]}
        fab = fabric(4, fault_sites=sites)
        out, trace = fab.allreduce(shards)
        ref = numpy_ring_allreduce(shards)
        for a, b in zip(out, ref):
            assert a.tobytes() == b.tobytes()
        # the injected stall shows up as backoff in the trace
        assert sum(p.backoff_cycles for p in trace.phases) >= 64

    def test_abort_policy_raises_and_posts_error_irq(self):
        from repro.core.backend import TransferError
        errors = []
        fab = fabric(
            2, error_policy=ErrorPolicy(action="abort"),
            fault_sites={0: [FaultSite(index=0, kind="persistent",
                                       hits=99)]})
        fab.engines[0].on_complete(
            lambda vec, evs: errors.extend(
                e for e in evs if e.status == "error"))
        with pytest.raises(TransferError):
            fab.allreduce(shards_for(2, 256, np.float32))
        assert errors, "abort must post an error completion interrupt"

    def test_plan_cache_shared_and_hit_across_iterations(self):
        """Iteration 2 of the same collective replays captured plans:
        the shared cache hit count strictly grows, and results stay
        byte-identical."""
        shards = shards_for(4, 1000, np.float32)
        fab = fabric(4)
        out1, _ = fab.allreduce(shards)
        pc = fab.engines[0].plan_cache
        assert pc is not None and pc is fab.engines[1].plan_cache
        h0 = pc.stats.hits
        out2, _ = fab.allreduce(shards)
        assert pc.stats.hits > h0
        for a, b in zip(out1, out2):
            assert a.tobytes() == b.tobytes()


class TestPhaseEngine:
    def test_completion_interrupts_drive_phases(self):
        """Every phase of the collective is pushed by the last rank's
        completion interrupt: engines' IrqControllers must each have
        fired once per phase the rank participated in."""
        fired = {r: 0 for r in range(4)}
        fab = fabric(4)
        for r in range(4):
            fab.engines[r].on_complete(
                lambda vec, evs, r=r: fired.__setitem__(
                    r, fired[r] + sum(1 for e in evs
                                      if e.status == "done")))
        _, trace = fab.allreduce(shards_for(4, 1024, np.float32))
        assert len(trace.phases) == 2 * (4 - 1)
        for r in range(4):
            assert fired[r] == len(trace.phases)

    def test_trace_accounting(self):
        shards = shards_for(2, 512, np.float32)
        _, trace = fabric(2).allreduce(shards)
        assert trace.total_cycles == sum(p.cycles for p in trace.phases)
        assert trace.total_bytes == sum(p.bytes_moved for p in trace.phases)
        assert trace.total_bytes > 0
        for p in trace.phases:
            assert p.cycles > 0 and p.streams

    def test_engine_stats_updated(self):
        fab = fabric(2)
        fab.allreduce(shards_for(2, 512, np.float32))
        for eng in fab.engines:
            assert eng.stats.submitted > 0
            assert eng.stats.completed == eng.stats.submitted

    def test_region_overflow_rejected(self):
        fab = CollectiveFabric(2, region_bytes=1 << 12)
        big = shards_for(2, 4096, np.float32)   # 16 KiB > 4 KiB region
        with pytest.raises(ValueError, match="region"):
            fab.allreduce(big)

    def test_world_shard_mismatch_rejected(self):
        with pytest.raises(ValueError):
            fabric(4).allreduce(shards_for(2, 64, np.float32))


class TestAnalyticPlans:
    def test_cycles_monotone_in_world_latency_regime(self):
        # tiny message: latency term dominates, more ranks cost more
        assert allreduce_cycles(1 << 10, 16) > allreduce_cycles(1 << 10, 4)

    def test_fabric_spec_shapes(self):
        spec = fabric_spec(4, region_bytes=1 << 16, channels=2)
        assert spec.channels.count == 2
        assert spec.mem_spaces[0][1] == 4 * (1 << 16)
        fab = CollectiveFabric(4, spec=spec)
        assert fab.region_bytes == 1 << 16
        assert len(fab.engines) == 4
        assert fab.engines[0].mem is fab.engines[3].mem
