"""Differential validation of the sanitizer's verdicts.

The contract (`repro.verify.adversary`): a sanitizer-clean program
produces byte-identical memory under every adversarial drain schedule,
and every program of the deliberately-racy family is flagged with its
expected code *and* observably diverges (or its overlap is a benign
same-value write).  The engine's ``schedule=`` / ``tie_seed=`` knobs
that make the adversary possible are pinned down here too.
"""

import pytest

from repro.verify import (RACY_KINDS, SCHEDULES, check_differential,
                          check_racy_seed, generate_program,
                          generate_racy_program, run_bytes,
                          sanitize_verdict, shrink_program)
from repro.verify.adversary import check_racy_program

#: fuzz depth: enough to cover every generator family / racy kind a few
#: times while keeping the tier-1 suite fast (CI runs thousands of seeds
#: through ``python -m repro.verify --differential``)
N_FUZZ = 40
N_RACY = 20


class TestDifferentialContract:
    @pytest.mark.parametrize("seed", range(N_FUZZ))
    def test_clean_programs_schedule_invariant(self, seed):
        # check_differential returns None when the contract holds:
        # sanitizer-clean -> byte-identical under all SCHEDULES;
        # sanitizer-flagged engine-family programs are skipped (racy
        # divergence is the racy family's contract, below)
        assert check_differential(generate_program(seed)) is None

    @pytest.mark.parametrize("seed", range(N_RACY))
    def test_racy_programs_flagged_and_diverge(self, seed):
        assert check_racy_seed(seed) is None

    def test_racy_kind_rotation_covered(self):
        kinds = {generate_racy_program(s)[1] for s in range(12)}
        # every racy kind's expected code shows up within a few seeds
        assert kinds == set().union(
            {__import__("repro.verify.generator", fromlist=["RACY_EXPECT"])
             .RACY_EXPECT[k] for k in RACY_KINDS})

    def test_wrong_expectation_is_caught(self):
        # the checker must not rubber-stamp: demanding a code the
        # sanitizer does not emit yields a divergence
        program, _ = generate_racy_program(0)
        d = check_racy_program(program, "H006")
        assert d is not None and "sanitize" in d.kind

    def test_racy_program_has_static_verdict(self):
        program, expected = generate_racy_program(1)
        report = sanitize_verdict(program)
        assert report.has(expected)


class TestAdversarialSchedules:
    def test_schedule_set_shape(self):
        # None + "reverse" covers both orders of every cross-channel
        # pair; the int seeds add interleavings between the extremes
        assert SCHEDULES[0] is None and "reverse" in SCHEDULES
        assert any(isinstance(s, int) for s in SCHEDULES)

    def test_same_seed_same_bytes(self):
        program = generate_program(3)
        a = run_bytes(program, 0xD1CE)
        b = run_bytes(program, 0xD1CE)
        assert a.spaces == b.spaces

    def test_tie_seed_is_timing_only(self):
        # tie_seed permutes simulator heap tie-breaking, never bytes
        program = generate_program(5)
        from repro.verify.harness import run_engine
        a = run_engine(program, tie_seed=None)
        b = run_engine(program, tie_seed=1234)
        assert a.spaces == b.spaces

    def test_reverse_schedule_flips_racy_outcome(self):
        # the cross-ww racy kind: last writer wins, so the natural and
        # reversed drains must land different bytes in the window
        for seed in range(8):
            program, kind = generate_racy_program(seed)
            if kind != "H003":
                continue
            nat = run_bytes(program, None)
            rev = run_bytes(program, "reverse")
            if nat.spaces != rev.spaces:
                return
        pytest.fail("no cross-channel racy seed diverged under reverse")


class TestRacyShrinker:
    def test_shrinks_preserving_divergence(self):
        program, expected = generate_racy_program(2)
        d = check_racy_program(program, expected)
        assert d is None    # healthy seed: flagged AND diverging

        # corrupt the expectation to get a reproducible divergence the
        # shrinker must preserve while minimizing
        def check(p):
            return check_racy_program(p, "H006")

        d = check(program)
        assert d is not None
        small, small_d = shrink_program(program, d, budget=60, check=check)
        assert small_d is not None and small_d.kind == d.kind
        assert small.num_rows <= program.num_rows
