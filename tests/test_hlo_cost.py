"""HLO cost-parser validation: trip-weighted flops vs analytical counts."""



class TestParser:
    def test_scan_matmul_flops(self, subproc):
        out = subproc("""
            import jax, jax.numpy as jnp
            from repro.launch.hlo_cost import analyze_hlo

            def f(x, w):
                def body(c, wi):
                    return jnp.tanh(c @ wi), None
                y, _ = jax.lax.scan(body, x, w)
                return y @ y.T

            x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
            w = jax.ShapeDtypeStruct((13, 128, 128), jnp.float32)
            comp = jax.jit(f).lower(x, w).compile()
            t = analyze_hlo(comp.as_text())
            expected = 13 * 2 * 128 ** 3 + 2 * 128 ** 3
            assert abs(t.flops / expected - 1) < 1e-6, (t.flops, expected)
            assert t.while_trips and t.while_trips[0][1] == 13
            # tanh transcendentals counted inside fusions
            assert t.transcendentals >= 13 * 128 * 128
            print("SCAN_FLOPS_OK")
        """, n_devices=1)
        assert "SCAN_FLOPS_OK" in out

    def test_sharded_matmul_per_device(self, subproc):
        out = subproc("""
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.hlo_cost import analyze_hlo
            mesh = jax.make_mesh((8,), ("model",))
            with mesh:
                g = jax.jit(lambda a, b: a @ b,
                            in_shardings=(NamedSharding(mesh, P(None, None)),
                                          NamedSharding(mesh, P(None, "model"))))
                c = g.lower(jax.ShapeDtypeStruct((256, 256), jnp.float32),
                            jax.ShapeDtypeStruct((256, 512), jnp.float32)
                            ).compile()
            t = analyze_hlo(c.as_text())
            assert abs(t.flops - 2 * 256 * 256 * 512 / 8) < 1e-6
            print("SHARDED_OK")
        """, n_devices=8)
        assert "SHARDED_OK" in out

    def test_collective_bytes_counted(self, subproc):
        out = subproc("""
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.hlo_cost import analyze_hlo
            mesh = jax.make_mesh((8,), ("d",))
            # contracting-dim sharded matmul forces an all-reduce
            with mesh:
                g = jax.jit(
                    lambda a, b: a @ b,
                    in_shardings=(NamedSharding(mesh, P(None, "d")),
                                  NamedSharding(mesh, P("d", None))),
                    out_shardings=NamedSharding(mesh, P()))
                c = g.lower(jax.ShapeDtypeStruct((64, 512), jnp.float32),
                            jax.ShapeDtypeStruct((512, 64), jnp.float32)
                            ).compile()
            t = analyze_hlo(c.as_text())
            # all-reduce of the (64, 64) f32 result
            assert t.total_collective_bytes >= 64 * 64 * 4
            print("COLL_OK", t.collective_bytes)
        """, n_devices=8)
        assert "COLL_OK" in out

    def test_shape_bytes(self):
        from repro.launch.hlo_cost import shape_bytes
        assert shape_bytes("f32[128,1024]{1,0}") == 128 * 1024 * 4
        assert shape_bytes("bf16[16]") == 32
        assert shape_bytes("(f32[8,4]{1,0}, pred[8])") == 8 * 4 * 4 + 8
        assert shape_bytes("s32[]") == 4
