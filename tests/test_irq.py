"""Completion-interrupt front-end (MSI-X style) + fault injection.

`IrqController` unit semantics (coalescing by count and by cycle window,
vector mapping, end-of-drain flush), engine-level delivery (interrupt
wait_all must be observationally identical to polling under any
`IrqSpec`), and the §2.3 error-handler verbs driven end-to-end through
seeded `FaultSite`s — transient recovery via replay, replay exhaustion
with backoff, continue skipping the offender, injected stalls.
"""

import numpy as np
import pytest

from repro.core import (CompletionEvent, DescriptorBatch, ErrorPolicy,
                        FaultInjector, FaultSite, IDMAEngine, IrqController,
                        IrqSpec, MemoryMap, Protocol, Transfer1D,
                        TransferError)


def ev(tid, cycle=0, channel=0, status="done", count=1, bytes_moved=64):
    return CompletionEvent(tid=tid, count=count, channel=channel,
                           cycle=cycle, status=status,
                           bytes_moved=bytes_moved)


def make_engine(**kw):
    mem = MemoryMap.create({Protocol.AXI4: 1 << 16, Protocol.OBI: 1 << 16})
    return IDMAEngine(mem=mem, **kw), mem


def fill(mem, proto, n, seed=0):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, n, dtype=np.uint8)
    mem.spaces[proto][:n] = data
    return data


#: disjoint destination window inside the AXI4 space
DST = 1 << 15


def rows(n, length=64, stride=256):
    """n disjoint AXI4→AXI4 rows: one legalized burst each at bus 8
    (OBI would split each row into single-beat bursts and shift the
    drain-global fault ordinals)."""
    return DescriptorBatch.from_arrays(
        src_addr=np.arange(n, dtype=np.int64) * stride,
        dst_addr=DST + np.arange(n, dtype=np.int64) * stride,
        length=np.full(n, length, dtype=np.int64),
        src_protocol=Protocol.AXI4, dst_protocol=Protocol.AXI4)


def dst_slice(mem, i, length=64, stride=256):
    lo = DST + i * stride
    return mem.spaces[Protocol.AXI4][lo:lo + length]


class TestIrqController:
    def test_count_coalescing_and_flush(self):
        fired = []
        ctl = IrqController(coalesce_count=2)
        ctl.register(lambda v, evs: fired.append((v, [e.tid for e in evs])))
        ctl.post(ev(1))
        assert fired == []                      # below threshold
        ctl.post(ev(2))
        assert fired == [(0, [1, 2])]           # threshold crossed
        ctl.post(ev(3))
        ctl.flush()                             # timeout kick
        assert fired == [(0, [1, 2]), (0, [3])]
        assert (ctl.stats.posted, ctl.stats.delivered,
                ctl.stats.fired, ctl.stats.flushed) == (3, 3, 2, 1)

    def test_cycle_window_coalescing(self):
        fired = []
        ctl = IrqController(coalesce_count=10, coalesce_cycles=16)
        ctl.register(lambda v, evs: fired.append([e.cycle for e in evs]))
        ctl.post(ev(1, cycle=0))
        ctl.post(ev(2, cycle=10))
        assert fired == []                      # window still open
        ctl.post(ev(3, cycle=16))               # newest - oldest >= 16
        assert fired == [[0, 10, 16]]

    def test_vector_mapping(self):
        fired = []
        ctl = IrqController(num_vectors=2)
        ctl.register(lambda v, evs: fired.append((v, evs[0].tid)))
        for tid, ch in ((1, 0), (2, 1), (3, 2), (4, -1)):
            ctl.post(ev(tid, channel=ch))
        # channel % vectors; sharded records (channel=-1) use vector 0
        assert fired == [(0, 1), (1, 2), (0, 3), (0, 4)]

    def test_flush_empty_is_silent(self):
        ctl = IrqController()
        ctl.flush()
        assert ctl.stats.fired == 0 and ctl.stats.flushed == 0

    @pytest.mark.parametrize("kw", [dict(num_vectors=0),
                                    dict(coalesce_count=0),
                                    dict(coalesce_cycles=-1)])
    def test_controller_validation(self, kw):
        with pytest.raises(ValueError):
            IrqController(**kw)

    @pytest.mark.parametrize("kw", [dict(coalesce_count=0),
                                    dict(coalesce_cycles=-1),
                                    dict(vectors=-1)])
    def test_spec_validation(self, kw):
        with pytest.raises(ValueError):
            IrqSpec(**kw)


class TestEngineDelivery:
    def test_events_cover_all_records_in_completion_order(self):
        eng, mem = make_engine()
        fill(mem, Protocol.AXI4, 1 << 12)
        got = []
        eng.on_complete(lambda v, evs: got.extend(evs))
        tids = [eng.submit_async(Transfer1D(i * 256, i * 256, 64,
                                            Protocol.AXI4, Protocol.OBI))
                for i in range(4)]
        eng.wait_all()
        assert [e.tid for e in got] == tids     # delivery == tid order here
        assert all(e.status == "done" for e in got)
        assert sum(e.bytes_moved for e in got) == eng.stats.bytes_moved
        assert [e.cycle for e in got] == sorted(e.cycle for e in got)
        assert all(eng.poll(t) == "done" for t in tids)

    def test_coalescing_is_observationally_inert(self):
        """Same program under immediate and heavily-coalesced IrqSpecs:
        identical cycles, bytes, and record outcomes — only the callback
        batching differs."""
        runs = {}
        for name, irq in (("imm", None),
                          ("coal", IrqSpec(coalesce_count=8,
                                           coalesce_cycles=64, vectors=1))):
            eng, mem = make_engine(num_channels=2, irq=irq)
            fill(mem, Protocol.AXI4, 1 << 12)
            batches = []
            eng.on_complete(lambda v, evs, b=batches: b.append(len(evs)))
            eng.dispatch_batch(rows(6))
            res = eng.wait_all()
            runs[name] = (res.aggregate.cycles,
                          tuple(r.cycles for r in res.per_channel),
                          eng.stats.bytes_moved,
                          [(r.tid, r.status) for r in eng._records],
                          mem.spaces[Protocol.AXI4].tobytes(), batches)
        assert runs["imm"][:5] == runs["coal"][:5]
        assert sum(runs["imm"][5]) == sum(runs["coal"][5])  # same events
        assert len(runs["coal"][5]) <= len(runs["imm"][5])  # fewer irqs

    def test_irq_vs_poll_identical_on_every_preset(self):
        """The generated-program harness view: on all four named presets
        an alternate interrupt shape changes nothing observable."""
        from repro.verify import generate_program
        from repro.verify.harness import run_engine
        alt = IrqSpec(coalesce_count=6, coalesce_cycles=40, vectors=1)
        for seed, family in enumerate(("pulp_cluster", "manticore",
                                       "cheshire", "edge_ai")):
            prog = generate_program(seed, family=family)
            base = run_engine(prog)
            irqd = run_engine(prog, irq_override=alt)
            assert base.spaces == irqd.spaces, family
            assert base.round_cycles == irqd.round_cycles, family
            assert base.channel_cycles == irqd.channel_cycles, family
            assert base.records == irqd.records, family
            assert sorted(base.events) == sorted(irqd.events), family


class TestFaultInjection:
    def test_transient_fault_recovered_by_replay(self):
        eng, mem = make_engine(
            error_policy=ErrorPolicy(action="replay", max_replays=3,
                                     replay_backoff=9))
        data = fill(mem, Protocol.AXI4, 1 << 12)
        eng.fault_injector = FaultInjector(
            [FaultSite(index=1, kind="transient", hits=2)])
        eng.dispatch_batch(rows(4))
        res = eng.wait_all()
        # burst 1 failed twice, replayed twice, then succeeded;
        # exponential backoff: 9 + 18 cycles for the two granted replays
        assert eng.stats.replays == 2 and eng.stats.errors == 2
        assert res.backoff_cycles == 27
        assert eng.stats.backoff_cycles == 27
        assert eng.stats.bytes_moved == 4 * 64
        for i in range(4):
            assert np.array_equal(dst_slice(mem, i),
                                  data[i * 256:i * 256 + 64])

    def test_replay_exhaustion_with_backoff(self):
        eng, mem = make_engine(
            error_policy=ErrorPolicy(action="replay", max_replays=2,
                                     replay_backoff=5))
        fill(mem, Protocol.AXI4, 1 << 12)
        eng.fault_injector = FaultInjector(
            [FaultSite(index=0, kind="persistent")])
        tids = eng.dispatch_batch(rows(2))
        with pytest.raises(TransferError, match="injected"):
            eng.wait_all()
        # 2 replays granted + the exhausting attempt; exponential
        # backoff (5 + 10) only for the granted replays, surfaced even
        # on the abort-out path
        assert eng.stats.replays == 3 and eng.stats.errors == 3
        assert eng.stats.backoff_cycles == 15
        assert eng.last_channel_result.backoff_cycles == 15
        assert eng.poll(tids[0]) == "error"

    def test_continue_skips_exactly_the_offender(self):
        eng, mem = make_engine(error_policy=ErrorPolicy(action="continue"))
        data = fill(mem, Protocol.AXI4, 1 << 12)
        eng.fault_injector = FaultInjector(
            [FaultSite(index=1, kind="persistent")])
        eng.dispatch_batch(rows(4))
        eng.wait_all()
        assert eng.stats.bytes_moved == 3 * 64
        for i in range(4):
            if i == 1:
                assert not dst_slice(mem, i).any()  # never written
            else:
                assert np.array_equal(dst_slice(mem, i),
                                      data[i * 256:i * 256 + 64])

    def test_stall_site_surfaces_on_backoff_cycles(self):
        eng, mem = make_engine()
        data = fill(mem, Protocol.AXI4, 1 << 12)
        eng.fault_injector = FaultInjector(
            [FaultSite(index=2, kind="stall", stall_cycles=25)])
        eng.dispatch_batch(rows(4))
        res = eng.wait_all()
        # a stall never fails the burst: full byte movement, timing hit
        assert eng.stats.errors == 0
        assert res.backoff_cycles == 25
        assert np.array_equal(dst_slice(mem, 0), data[:64])
        assert eng.stats.bytes_moved == 4 * 64


class TestKVCacheNotification:
    def test_functional_path_posts_synthetic_events(self):
        jax = pytest.importorskip("jax")  # noqa: F841
        from repro.serve.kvcache import KVLayout, PagedKVDMA, PagePool, \
            make_page_tables
        layout = KVLayout(16, 4, 2, 8, itemsize=4)
        got = []
        dma = PagedKVDMA(layout, max_batch=2, max_len=8, timing=False,
                         on_complete=lambda v, evs: got.extend(evs))
        tables = make_page_tables(PagePool(16, 4), 2, 8)
        rng = np.random.default_rng(0)
        k = rng.standard_normal((2, 2, 8)).astype(np.float32)
        dma.append(tables, 0, k, k)
        assert got and got[-1].status == "done"
        assert got[-1].bytes_moved > 0
        assert got[-1].tid == -1                # synthetic: no drain ids
