"""Vectorized functional data plane: `execute_batch` vs the scalar oracle.

The grouped gather/scatter back-end must be byte-identical to running the
scalar `execute` over the same legalized bursts — across every protocol
pair, all three Init patterns, in-stream accelerators, nonzero stream
bases, and every error-handler verb.  The scalar path stays in the tree
exactly so these tests have an oracle.

Also covers the back-end bugfixes that ride along:
* `MemoryMap.read`/`write` reject negative addresses (slice wrap-around),
* `stream_base` actually applies to generator fetches,
* `TransferError.index` names the offender exactly (duplicate bursts).
"""

import dataclasses
import random

import numpy as np
import pytest

from repro.core import (BackendOptions, DescriptorBatch, ErrorPolicy,
                        IDMAEngine, InitPattern, MemoryMap, Protocol,
                        Transfer1D, TransferError, check_legal,
                        check_legal_batch, execute, execute_batch,
                        init_stream, legalize, legalize_batch)

MEM_PROTOS = [Protocol.AXI4, Protocol.AXI_LITE, Protocol.AXI_STREAM,
              Protocol.OBI, Protocol.TILELINK, Protocol.HBM, Protocol.VMEM]
SPACE = 1 << 16
PATTERNS = list(InitPattern)


def make_mem(seed=0):
    mem = MemoryMap.create({p: SPACE for p in MEM_PROTOS})
    rng = np.random.default_rng(seed)
    for p in MEM_PROTOS:
        mem.spaces[p][:] = rng.integers(0, 256, SPACE, dtype=np.uint8)
    return mem


def rand_legal_batch(rng, n_transfers):
    """Random legalized stream over all protocol pairs and Init patterns.

    Sources read from the lower half of each space, destinations are
    bump-allocated from the upper half, so no burst reads bytes another
    burst writes (the documented no-RAW contract of `execute_batch`).
    """
    ts = []
    cursor = {p: SPACE // 2 for p in MEM_PROTOS}
    for i in range(n_transfers):
        sp = rng.choice(MEM_PROTOS + [Protocol.INIT])
        dp = rng.choice(MEM_PROTOS)
        length = rng.choice([0, 1, 3, 17, 255, 1000, rng.randrange(2000)])
        if cursor[dp] + length > SPACE:
            continue
        dst = cursor[dp]
        cursor[dp] += length
        src = rng.randrange(0, SPACE // 2 - length) \
            if sp is not Protocol.INIT else rng.randrange(0, 5000)
        opts = BackendOptions(
            max_burst=rng.choice([0, 0, 7, 64, 1000]),
            reduce_len=rng.choice([0, 0, 33]),
            init_pattern=rng.choice(PATTERNS),
            init_value=rng.randrange(0, 1000))
        ts.append(Transfer1D(src, dst, length, sp, dp, options=opts,
                             transfer_id=i))
    return legalize_batch(DescriptorBatch.from_transfers(ts), bus_width=8)


def assert_spaces_equal(m1, m2, ctx=""):
    for p in MEM_PROTOS:
        assert np.array_equal(m1.spaces[p], m2.spaces[p]), f"{ctx}: {p}"


class TestExecuteBatchOracle:
    def test_randomized_all_protocol_pairs(self):
        """Acceptance: byte-identical to scalar `execute` on randomized
        legalized batches (all protocol pairs, all Init patterns)."""
        rng = random.Random(11)
        for trial in range(30):
            legal = rand_legal_batch(rng, rng.randrange(1, 14))
            m1, m2 = make_mem(trial), make_mem(trial)
            a = execute(legal.to_transfers(), m1, bus_width=8)
            b = execute_batch(legal, m2, bus_width=8)
            assert a == b, f"trial {trial}"
            assert_spaces_equal(m1, m2, f"trial {trial}")

    def test_every_pair_and_pattern_systematically(self):
        """One page-straddling transfer per (src, dst) pair, one per Init
        pattern — no pair rides only on random coverage."""
        srcs = [(p, None) for p in MEM_PROTOS] + \
            [(Protocol.INIT, pat) for pat in PATTERNS]
        for sp, pat in srcs:
            for dp in MEM_PROTOS:
                opts = BackendOptions() if pat is None else BackendOptions(
                    init_pattern=pat, init_value=0x5A)
                t = Transfer1D(4096 - 3, SPACE // 2 + 4096 - 9, 5000,
                               sp, dp, options=opts)
                legal = legalize_batch(
                    DescriptorBatch.from_transfers([t]), bus_width=8)
                m1, m2 = make_mem(7), make_mem(7)
                execute(legal.to_transfers(), m1, bus_width=8)
                execute_batch(legal, m2, bus_width=8)
                assert_spaces_equal(m1, m2, f"{sp}->{dp} {pat}")

    def test_instream_applied_per_chunk(self):
        """The in-stream accelerator runs per burst chunk on both paths."""
        sizes1, sizes2 = [], []

        def xform(track):
            def f(d):
                track.append(d.shape[0])
                return 255 - d
            return f

        rng = random.Random(3)
        legal = rand_legal_batch(rng, 10)
        m1, m2 = make_mem(1), make_mem(1)
        execute(legal.to_transfers(), m1, bus_width=8,
                instream=xform(sizes1))
        execute_batch(legal, m2, bus_width=8, instream=xform(sizes2))
        assert_spaces_equal(m1, m2)
        assert sorted(sizes1) == sorted(sizes2)   # same chunking granularity

    def test_empty_batch(self):
        assert execute_batch(DescriptorBatch.empty(), make_mem()) == 0


class TestStreamBase:
    OPTS = BackendOptions(init_pattern=InitPattern.PSEUDORANDOM,
                          init_value=7)

    def test_nonzero_base_applies_to_generator_fetch(self):
        """Regression: the per-transfer-id origin was computed but never
        applied — a nonzero `stream_base` must shift the Init stream."""
        t = Transfer1D(100, 0, 256, Protocol.INIT, Protocol.OBI,
                       options=self.OPTS, transfer_id=3)
        bursts = legalize(t, bus_width=8)
        mem = make_mem()
        execute(bursts, mem, bus_width=8, stream_base={3: 100})
        want = init_stream(InitPattern.PSEUDORANDOM, 7, 0, 256)
        assert np.array_equal(mem.spaces[Protocol.OBI][:256], want)

    def test_default_base_is_absolute_offset(self):
        """Docstring contract: without `stream_base` the stream offset is
        the absolute source address, so any split reproduces the unsplit
        stream."""
        t = Transfer1D(100, 0, 256, Protocol.INIT, Protocol.OBI,
                       options=self.OPTS)
        mem = make_mem()
        execute(legalize(t, bus_width=8), mem, bus_width=8)
        want = init_stream(InitPattern.PSEUDORANDOM, 7, 100, 256)
        assert np.array_equal(mem.spaces[Protocol.OBI][:256], want)

    def test_split_across_calls_same_stream(self):
        """A legalized Init transfer split across separate execute calls
        (distinct back-end ports, replays) produces the unsplit stream."""
        t = Transfer1D(64, 0, 1024, Protocol.INIT, Protocol.OBI,
                       options=self.OPTS, transfer_id=9)
        bursts = legalize(dataclasses.replace(
            t, options=dataclasses.replace(self.OPTS, max_burst=96)),
            bus_width=8)
        assert len(bursts) > 2
        mem = make_mem()
        base = {9: 64}
        for b in bursts:            # one call per burst: worst-case split
            execute([b], mem, bus_width=8, stream_base=base)
        want = init_stream(InitPattern.PSEUDORANDOM, 7, 0, 1024)
        assert np.array_equal(mem.spaces[Protocol.OBI][:1024], want)

    def test_batch_matches_scalar_with_base(self):
        t = Transfer1D(40, 0, 512, Protocol.INIT, Protocol.OBI,
                       options=self.OPTS, transfer_id=5)
        legal = legalize_batch(DescriptorBatch.from_transfers([t]), 8)
        m1, m2 = make_mem(), make_mem()
        execute(legal.to_transfers(), m1, bus_width=8, stream_base={5: 24})
        execute_batch(legal, m2, bus_width=8, stream_base={5: 24})
        assert_spaces_equal(m1, m2)


class TestMemoryMapBounds:
    def test_negative_read_rejected(self):
        """Regression: a negative address passed the end-of-buffer guard
        and silently wrapped via Python slice semantics."""
        mem = make_mem()
        with pytest.raises(IndexError, match="negative"):
            mem.read(Protocol.AXI4, -4, 4)

    def test_negative_write_rejected(self):
        mem = make_mem()
        before = mem.spaces[Protocol.AXI4].copy()
        with pytest.raises(IndexError, match="negative"):
            mem.write(Protocol.AXI4, -8, np.zeros(4, dtype=np.uint8))
        assert np.array_equal(mem.spaces[Protocol.AXI4], before)

    def test_negative_row_in_batch_is_a_transfer_error(self):
        """execute_batch must not let fancy indexing wrap a negative row."""
        batch = DescriptorBatch.from_arrays(
            src_addr=np.array([0, -64]), dst_addr=np.array([0, 64]),
            length=np.array([64, 64]),
            src_protocol=Protocol.HBM, dst_protocol=Protocol.VMEM)
        mem = make_mem()
        before = mem.spaces[Protocol.VMEM].copy()
        with pytest.raises(TransferError) as ei:
            execute_batch(batch, mem, bus_width=8)
        assert ei.value.index == 1
        assert "negative" in ei.value.reason
        # row 0 executed, row 1 had no effect
        assert np.array_equal(mem.spaces[Protocol.VMEM][:64],
                              mem.spaces[Protocol.HBM][:64])
        assert np.array_equal(mem.spaces[Protocol.VMEM][64:], before[64:])


class TestTransferErrorIndex:
    def test_injected_fault_reports_index(self):
        legal = rand_legal_batch(random.Random(5), 8)
        k = len(legal) // 2
        m1, m2 = make_mem(), make_mem()
        with pytest.raises(TransferError) as e1:
            execute(legal.to_transfers(), m1, bus_width=8, fail_at=k)
        with pytest.raises(TransferError) as e2:
            execute_batch(legal, m2, bus_width=8, fail_at=k)
        assert e1.value.index == e2.value.index == k
        assert_spaces_equal(m1, m2, "partial state at fault")

    def test_duplicate_bursts_get_exact_index(self):
        """Identical rows are indistinguishable by value — the index must
        still name the actual offender."""
        row = dict(src_addr=np.array([0, 128, 0]),
                   dst_addr=np.array([0, 128, 0]),
                   length=np.array([64, 64, 64]))
        batch = DescriptorBatch.from_arrays(
            src_protocol=Protocol.HBM, dst_protocol=Protocol.VMEM, **row)
        with pytest.raises(TransferError) as ei:
            execute_batch(batch, make_mem(), bus_width=8, fail_at=2)
        assert ei.value.index == 2

    def test_out_of_bounds_burst_reports_index_and_partial_state(self):
        batch = DescriptorBatch.from_arrays(
            src_addr=np.array([0, SPACE + 64, 128]),
            dst_addr=np.array([0, 64, 128]),
            length=np.array([64, 64, 64]),
            src_protocol=Protocol.HBM, dst_protocol=Protocol.VMEM)
        mem = make_mem()
        with pytest.raises(TransferError) as ei:
            execute_batch(batch, mem, bus_width=8)
        assert ei.value.index == 1
        assert "beyond" in ei.value.reason
        assert np.array_equal(mem.spaces[Protocol.VMEM][:64],
                              mem.spaces[Protocol.HBM][:64])


def scalar_run_oracle(eng, transfer, fail_at, stats):
    """The engine's error-policy loop expressed over the scalar back-end
    (`execute` + object burst lists) — the oracle for `_run`."""
    ports = eng.lower(transfer)
    fail_pending = fail_at
    for bursts in ports:
        done = 0
        replays = 0
        while done < len(bursts):
            fail = None
            if fail_pending is not None and \
                    done <= fail_pending < len(bursts):
                fail = fail_pending - done
            try:
                stats["bytes"] += execute(
                    bursts[done:], eng.mem, bus_width=eng.bus_width,
                    fail_at=fail)
                done = len(bursts)
            except TransferError as err:
                idx = done + err.index
                stats["errors"] += 1
                stats["bytes"] += sum(b.length for b in bursts[done:idx])
                action = eng.error_policy.action
                if action == "abort":
                    raise
                if action == "continue":
                    fail_pending = None
                    done = idx + 1
                    continue
                replays += 1
                stats["replays"] += 1
                if replays > eng.error_policy.max_replays:
                    raise
                fail_pending = None
                done = idx


class TestEnginePolicyMatrix:
    """Satellite: abort/continue/replay x fault at first/middle/last burst
    x multi-back-end port split — byte-identical to the scalar oracle."""

    @staticmethod
    def build(action, backends):
        kw = dict(num_backends=backends, backend_boundary=512) \
            if backends > 1 else {}
        mem = MemoryMap.create({Protocol.AXI4: 1 << 14,
                                Protocol.OBI: 1 << 14})
        rng = np.random.default_rng(5)
        mem.spaces[Protocol.AXI4][:] = rng.integers(
            0, 256, 1 << 14, dtype=np.uint8)
        return IDMAEngine(mem=mem,
                          error_policy=ErrorPolicy(action=action), **kw), mem

    @pytest.mark.parametrize("action", ["abort", "continue", "replay"])
    @pytest.mark.parametrize("pos", ["first", "middle", "last"])
    @pytest.mark.parametrize("backends", [1, 2])
    def test_policy_fault_position_backends(self, action, pos, backends):
        t = Transfer1D(0, 0, 4096, Protocol.AXI4, Protocol.OBI)
        probe, _ = self.build(action, backends)
        n0 = len(probe.lower(t)[0])
        assert n0 >= 3
        fail = {"first": 0, "middle": n0 // 2, "last": n0 - 1}[pos]

        eng, mem = self.build(action, backends)
        eng.inject_fault(fail)
        raised = None
        try:
            eng.submit(t)
        except TransferError as err:
            raised = err

        oracle, mem2 = self.build(action, backends)
        stats = {"bytes": 0, "errors": 0, "replays": 0}
        oracle_raised = None
        try:
            scalar_run_oracle(oracle, dataclasses.replace(t, transfer_id=1),
                              fail, stats)
        except TransferError as err:
            oracle_raised = err

        assert (raised is None) == (oracle_raised is None)
        for p in (Protocol.AXI4, Protocol.OBI):
            assert np.array_equal(mem.spaces[p], mem2.spaces[p]), \
                f"{action}/{pos}/{backends}: {p}"
        assert eng.stats.bytes_moved == stats["bytes"]
        assert eng.stats.errors == stats["errors"]
        assert eng.stats.replays == stats["replays"]

    def test_replay_through_batch_payload(self):
        """dispatch_batch traffic heals through the replay verb too."""
        eng, mem = self.build("replay", 1)
        batch = DescriptorBatch.from_arrays(
            src_addr=np.arange(4, dtype=np.int64) * 256,
            dst_addr=np.arange(4, dtype=np.int64) * 256,
            length=np.full(4, 256, dtype=np.int64),
            src_protocol=Protocol.AXI4, dst_protocol=Protocol.OBI)
        eng.inject_fault(2)
        eng.dispatch_batch(batch)
        eng.wait_all()
        assert eng.stats.replays == 1 and eng.stats.errors == 1
        assert np.array_equal(mem.spaces[Protocol.OBI][:1024],
                              mem.spaces[Protocol.AXI4][:1024])


class TestInitSplitInvariance:
    def test_same_stream_across_backend_split(self):
        """An Init transfer mp_dist'ed over 4 back-end ports writes the
        same bytes as the single-port engine."""
        opts = BackendOptions(init_pattern=InitPattern.PSEUDORANDOM,
                              init_value=5)
        results = []
        for nb in (1, 4):
            kw = dict(num_backends=nb, backend_boundary=256) \
                if nb > 1 else {}
            mem = MemoryMap.create({Protocol.OBI: 1 << 13})
            eng = IDMAEngine(mem=mem, **kw)
            eng.submit(Transfer1D(0, 0, 4096, Protocol.INIT, Protocol.OBI,
                                  options=opts))
            results.append(mem.spaces[Protocol.OBI][:4096].copy())
        want = init_stream(InitPattern.PSEUDORANDOM, 5, 0, 4096)
        assert np.array_equal(results[0], want)
        assert np.array_equal(results[1], want)


class TestCheckLegalBatch:
    def rand_raw(self, rng, n):
        ts = []
        for i in range(n):
            sp = rng.choice(MEM_PROTOS + [Protocol.INIT])
            dp = rng.choice(MEM_PROTOS)
            ts.append(Transfer1D(
                rng.randrange(0, 1 << 30), rng.randrange(0, 1 << 30),
                rng.choice([1, 3, 64, 255, 4096, 10000]), sp, dp,
                transfer_id=i))
        return ts

    def test_matches_scalar_raise_and_message(self):
        rng = random.Random(21)
        raised = 0
        for trial in range(80):
            ts = self.rand_raw(rng, rng.randrange(1, 10))
            batch = DescriptorBatch.from_transfers(ts)
            err_obj = err_bat = None
            try:
                check_legal(ts, 8)
            except ValueError as e:
                err_obj = str(e)
            try:
                check_legal_batch(batch, 8)
            except ValueError as e:
                err_bat = str(e)
            assert (err_obj is None) == (err_bat is None), f"trial {trial}"
            if err_obj is not None:
                assert err_obj == err_bat, f"trial {trial}"
                raised += 1
        assert raised > 10       # the sweep actually exercised violations

    def test_legalized_output_passes(self):
        legal = rand_legal_batch(random.Random(2), 12)
        check_legal_batch(legal, 8)


class TestNoObjectMaterialization:
    def test_run_path_never_calls_to_transfers(self, monkeypatch):
        """Acceptance: the functional hot path stays on arrays end-to-end
        — submit and dispatch_batch work with to_transfers() poisoned."""
        mem = MemoryMap.create({Protocol.AXI4: 1 << 13, Protocol.OBI: 1 << 13})
        rng = np.random.default_rng(0)
        data = rng.integers(0, 256, 4096, dtype=np.uint8)
        mem.spaces[Protocol.AXI4][:4096] = data
        eng = IDMAEngine(mem=mem, num_backends=2, backend_boundary=512,
                         num_channels=2)

        def boom(self):
            raise AssertionError("to_transfers() on the data plane")

        monkeypatch.setattr(DescriptorBatch, "to_transfers", boom)
        eng.submit(Transfer1D(0, 0, 2048, Protocol.AXI4, Protocol.OBI))
        batch = DescriptorBatch.from_arrays(
            src_addr=np.array([2048, 3072]), dst_addr=np.array([2048, 3072]),
            length=np.array([1024, 1024]),
            src_protocol=Protocol.AXI4, dst_protocol=Protocol.OBI)
        eng.dispatch_batch(batch)
        eng.wait_all()
        assert np.array_equal(mem.spaces[Protocol.OBI][:4096], data[:4096])
