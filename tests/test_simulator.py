"""Timing-model tests: the paper's §3/§4 performance claims."""


from repro.core import (HBM, PULP_L2, RPC_DRAM, SRAM, EngineConfig,
                        MemSystem, Protocol, Transfer1D,
                        cheshire_idma_config, fragmented_copy,
                        pulp_idma_config, simulate, utilization_sweep,
                        xilinx_baseline_config)
from repro.core.simulator import PULP_TCDM


class TestLatencyClaims:
    """§4.3: 2 cycles descriptor → first read request; +1 per mid-end."""

    def test_two_cycle_launch(self):
        cfg = EngineConfig(bus_width=8)
        r = simulate([Transfer1D(0, 0, 64)], cfg, SRAM, SRAM)
        assert r.first_read_req == 2

    def test_one_cycle_without_legalizer(self):
        cfg = EngineConfig(bus_width=8, has_legalizer=False)
        r = simulate([Transfer1D(0, 0, 64)], cfg, SRAM, SRAM)
        assert r.first_read_req == 1

    def test_midend_adds_one(self):
        cfg = EngineConfig(bus_width=8, num_midends=1)
        r = simulate([Transfer1D(0, 0, 64)], cfg, SRAM, SRAM)
        assert r.first_read_req == 3

    def test_tensor_nd_zero_latency_config(self):
        cfg = EngineConfig(bus_width=8, num_midends=1,
                           tensor_nd_zero_latency=True)
        r = simulate([Transfer1D(0, 0, 64)], cfg, SRAM, SRAM)
        assert r.first_read_req == 2


class TestUtilizationClaims:
    def test_hbm_16B_at_full_outstanding(self):
        """§6: 'almost perfect bus utilization for 16 B-long transfers when
        accessing an endpoint with 100 cycles of latency' (32-b config)."""
        cfg = EngineConfig(bus_width=4, n_outstanding=64)
        r = fragmented_copy(64 * 1024, 16, cfg, HBM, HBM)
        assert r.utilization > 0.97

    def test_deep_memory_hidden_with_enough_outstanding(self):
        """Fig. 14: utilization improves with NAx until saturation."""
        utils = []
        for nax in (2, 8, 64):
            cfg = EngineConfig(bus_width=4, n_outstanding=nax)
            utils.append(fragmented_copy(64 * 1024, 64, cfg, HBM, HBM)
                         .utilization)
        assert utils[0] < utils[1] <= utils[2] + 1e-9
        assert utils[2] > 0.97

    def test_sub_bus_transfers_drop(self):
        """'Any transfers smaller than the bus width will inevitably lead
        to a substantial drop in utilization.'"""
        cfg = EngineConfig(bus_width=8, n_outstanding=64)
        r = fragmented_copy(64 * 1024, 2, cfg, SRAM, SRAM)
        assert r.utilization < 0.3

    def test_full_bus_utilization_at_16B_32b(self):
        """§1: 'full bus utilization on transfers as small as 16 B'
        (32-b configuration, shallow memory)."""
        cfg = EngineConfig(bus_width=4, n_outstanding=16)
        r = fragmented_copy(64 * 1024, 16, cfg, SRAM, SRAM)
        assert r.utilization > 0.97


class TestSystemClaims:
    def test_pulp_8kib_1107_cycles(self):
        """§3.1: 8 KiB TCDM→L2 measured at 1107 cycles (ideal 1024)."""
        r = simulate([Transfer1D(0, 0, 8192, Protocol.OBI, Protocol.AXI4)],
                     pulp_idma_config(), PULP_TCDM, PULP_L2)
        assert abs(r.cycles - 1107) / 1107 < 0.02

    def test_cheshire_6x_over_xilinx_at_64B(self):
        """§3.3: ~6× bus utilization over AXI DMA v7.1 at 64-B transfers,
        iDMA near-perfect."""
        l2 = MemSystem("SPM", 10, 8)
        ri = fragmented_copy(64 * 1024, 64, cheshire_idma_config(), l2, l2)
        rx = fragmented_copy(64 * 1024, 64, xilinx_baseline_config(), l2, l2)
        ratio = ri.utilization / rx.utilization
        assert ri.utilization > 0.95
        assert 5.0 < ratio < 7.0

    def test_decoupling_wins(self):
        """Read/write decoupling beats store-and-forward at any size."""
        l2 = MemSystem("SPM", 10, 8)
        for frag in (16, 64, 256, 1024):
            rd = fragmented_copy(64 * 1024, frag,
                                 EngineConfig(bus_width=8, n_outstanding=8,
                                              decoupled=True), l2, l2)
            rc = fragmented_copy(64 * 1024, frag,
                                 EngineConfig(bus_width=8, n_outstanding=8,
                                              decoupled=False,
                                              exclusive_transfers=True),
                                 l2, l2)
            assert rd.utilization > rc.utilization


class TestSweep:
    def test_sweep_monotone_in_fragment_size(self):
        cfg = EngineConfig(bus_width=4, n_outstanding=16)
        u = utilization_sweep(cfg, RPC_DRAM)
        vals = [u[k] for k in sorted(u)]
        assert all(b >= a - 1e-9 for a, b in zip(vals, vals[1:]))
