"""Paged KV cache via the descriptor plane: paged-via-DMA == contiguous.

The serving engine's decode-step cache traffic — token append (scatter)
and page gather — expressed as `DescriptorBatch` transfers through an
`IDMAEngine` must produce byte-identical results to the jax paged
reference (`append_token`/`gather_kv`), which itself round-trips the
contiguous cache.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.serve.kvcache import (KVLayout, PagedKVDMA, PagePool,  # noqa: E402
                                 append_descriptors, append_token,
                                 gather_descriptors, gather_kv,
                                 init_paged_kv, make_page_tables)

N_PAGES, PAGE_SIZE, HKV, DH = 16, 4, 2, 8
B, STEPS = 3, 8


def layout():
    return KVLayout(N_PAGES, PAGE_SIZE, HKV, DH, itemsize=4)


def run_both_paths(seed=0, num_channels=1, timing=True):
    rng = np.random.default_rng(seed)
    pool = init_paged_kv(N_PAGES, PAGE_SIZE, HKV, DH, dtype=jnp.float32)
    tables = make_page_tables(PagePool(N_PAGES, PAGE_SIZE), B, STEPS)
    dma = PagedKVDMA(layout(), max_batch=B, max_len=STEPS,
                     num_channels=num_channels, timing=timing)
    for pos in range(STEPS):
        k = rng.standard_normal((B, HKV, DH)).astype(np.float32)
        v = rng.standard_normal((B, HKV, DH)).astype(np.float32)
        pool = append_token(pool, jnp.asarray(tables), jnp.int32(pos),
                            jnp.asarray(k), jnp.asarray(v), PAGE_SIZE)
        dma.append(tables, pos, k, v)
    k_ref, v_ref = gather_kv(pool, jnp.asarray(tables), STEPS, PAGE_SIZE)
    k_dma, v_dma = dma.gather(tables, STEPS)
    return (np.asarray(k_ref), np.asarray(v_ref)), (k_dma, v_dma), dma


class TestPagedKVDMA:
    def test_paged_via_dma_equals_contiguous(self):
        (k_ref, v_ref), (k_dma, v_dma), _ = run_both_paths()
        assert np.array_equal(k_ref, k_dma)
        assert np.array_equal(v_ref, v_dma)

    def test_multi_channel_engine_same_bytes(self):
        (k_ref, v_ref), (k_dma, v_dma), dma = run_both_paths(seed=1,
                                                             num_channels=4)
        assert np.array_equal(k_ref, k_dma)
        assert np.array_equal(v_ref, v_dma)
        assert len(dma.engine.last_channel_result.per_channel) == 4

    def test_functional_only_path_same_bytes(self):
        """timing=False drives the same descriptors straight through the
        vectorized data plane (`execute_batch`): identical bytes, no
        timing simulation, byte stats still tracked."""
        (k_ref, v_ref), (k_dma, v_dma), dma = run_both_paths(seed=5,
                                                             timing=False)
        assert np.array_equal(k_ref, k_dma)
        assert np.array_equal(v_ref, v_dma)
        assert dma.engine.last_channel_result is None     # no cycle model
        lay = layout()
        want = (STEPS * B * lay.row_bytes * 2
                + B * (STEPS // PAGE_SIZE) * lay.page_bytes * 2)
        assert dma.engine.stats.bytes_moved == want

    def test_traffic_is_engine_transfers(self):
        _, _, dma = run_both_paths(seed=2)
        lay = layout()
        # appends: STEPS tokens x B rows x {k, v}; gathers: the page walk
        append_bytes = STEPS * B * lay.row_bytes * 2
        gather_bytes = B * (STEPS // PAGE_SIZE) * lay.page_bytes * 2
        assert dma.engine.stats.bytes_moved == append_bytes + gather_bytes
        assert dma.engine.stats.errors == 0

    def test_gather_partial_page_truncates_like_reference(self):
        """max_len not a page multiple: both paths gather whole pages
        only, with identical shapes and bytes."""
        rng = np.random.default_rng(3)
        pool = init_paged_kv(N_PAGES, PAGE_SIZE, HKV, DH, dtype=jnp.float32)
        tables = make_page_tables(PagePool(N_PAGES, PAGE_SIZE), B, STEPS)
        dma = PagedKVDMA(layout(), max_batch=B, max_len=STEPS)
        for pos in range(STEPS):
            k = rng.standard_normal((B, HKV, DH)).astype(np.float32)
            v = rng.standard_normal((B, HKV, DH)).astype(np.float32)
            pool = append_token(pool, jnp.asarray(tables), jnp.int32(pos),
                                jnp.asarray(k), jnp.asarray(v), PAGE_SIZE)
            dma.append(tables, pos, k, v)
        max_len = PAGE_SIZE + 2                       # not a page multiple
        k_ref, _ = gather_kv(pool, jnp.asarray(tables), max_len, PAGE_SIZE)
        k_dma, _ = dma.gather(tables, max_len)
        assert k_dma.shape == np.asarray(k_ref).shape
        assert np.array_equal(np.asarray(k_ref), k_dma)

    def test_gather_results_do_not_alias_vmem(self):
        """A second gather must not mutate a previously returned array."""
        _, (k1, _), dma = run_both_paths(seed=4)
        tables = make_page_tables(PagePool(N_PAGES, PAGE_SIZE), B, STEPS)
        snapshot = k1.copy()
        dma._pool("k")[:] = 0                      # wipe the physical pool
        zeros, _ = dma.gather(tables, STEPS)       # reuses the VMEM region
        assert np.array_equal(k1, snapshot)        # old result untouched
        assert not np.array_equal(zeros, k1)
        assert not zeros.any()

    def test_descriptor_builders_shapes(self):
        lay = layout()
        tables = make_page_tables(PagePool(N_PAGES, PAGE_SIZE), B, STEPS)
        g = gather_descriptors(lay, tables, STEPS)
        assert len(g) == B * (STEPS // PAGE_SIZE)
        assert int(g.length.sum()) == B * STEPS * lay.row_bytes
        assert (g.length == lay.page_bytes).all()
        a = append_descriptors(lay, tables, pos=5)
        assert len(a) == B
        assert (a.length == lay.row_bytes).all()
        # scatter targets: page for token 5 with in-page offset 1
        phys = tables[:, 5 // PAGE_SIZE].astype(np.int64)
        want = phys * lay.page_bytes + (5 % PAGE_SIZE) * lay.row_bytes
        assert np.array_equal(a.dst_addr, want)

    def test_gather_matches_manual_page_walk(self):
        """Descriptor addressing: src of row (b, i) is page_table[b, i]'s
        byte offset in the pool."""
        lay = layout()
        tables = make_page_tables(PagePool(N_PAGES, PAGE_SIZE), B, STEPS)
        g = gather_descriptors(lay, tables, STEPS)
        n = STEPS // PAGE_SIZE
        want_src = (tables[:, :n].astype(np.int64).reshape(-1)
                    * lay.page_bytes)
        assert np.array_equal(g.src_addr, want_src)
        assert int(g.src_proto[0]) != int(g.dst_proto[0])  # HBM -> VMEM
