"""System-level property tests (hypothesis) on framework invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (EngineConfig, MemSystem, Transfer1D, simulate)


@settings(max_examples=60, deadline=None)
@given(
    frag=st.sampled_from([4, 16, 64, 256]),
    nax_small=st.integers(1, 8),
    extra=st.integers(1, 56),
    latency=st.integers(1, 200),
)
def test_utilization_bounded_and_monotone_in_nax(frag, nax_small, extra,
                                                 latency):
    """0 < util <= 1, and more outstanding transactions never hurt."""
    mem = MemSystem("m", latency=latency, outstanding=64)
    ts = [Transfer1D(i * frag, i * frag, frag) for i in range(256)]
    lo = simulate(ts, EngineConfig(bus_width=4, n_outstanding=nax_small),
                  mem, mem).utilization
    hi = simulate(ts, EngineConfig(bus_width=4,
                                   n_outstanding=nax_small + extra),
                  mem, mem).utilization
    assert 0 < lo <= 1.0 + 1e-9
    assert hi >= lo - 1e-9


@settings(max_examples=60, deadline=None)
@given(latency=st.integers(1, 300))
def test_latency_never_leaks_into_launch(latency):
    """First read request is always exactly the §4.3 launch latency,
    independent of memory depth."""
    mem = MemSystem("m", latency=latency, outstanding=8)
    r = simulate([Transfer1D(0, 0, 256)], EngineConfig(bus_width=8),
                 mem, mem)
    assert r.first_read_req == 2


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(8, 64),
    e=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 2),
    seed=st.integers(0, 2 ** 16),
)
def test_moe_dispatch_conserves_tokens(t, e, k, seed):
    """With ample capacity, the sort/scatter/gather dispatch is exact:
    y == sum_k gate_k * expert_k(x) computed densely."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import moe_dispatch_compute
    import math as m

    d, f = 16, 32
    rng = np.random.default_rng(seed)
    mc = MoEConfig(n_experts=e, top_k=k, d_ff_expert=f,
                   capacity_factor=float(e))      # dropless at these sizes
    p = {
        "router": {"kernel": jnp.asarray(
            rng.standard_normal((d, e)) * 0.5, jnp.float32)},
        "w_gate": jnp.asarray(rng.standard_normal((e, d, f)) / m.sqrt(d),
                              jnp.float32),
        "w_up": jnp.asarray(rng.standard_normal((e, d, f)) / m.sqrt(d),
                            jnp.float32),
        "w_down": jnp.asarray(rng.standard_normal((e, f, d)) / m.sqrt(f),
                              jnp.float32),
    }
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    y, aux, dropped = moe_dispatch_compute(p, x, mc, "silu", jnp.float32)
    assert float(dropped) == 0.0

    # dense reference
    logits = x @ p["router"]["kernel"]
    probs = jax.nn.softmax(logits, axis=-1)
    gv, idx = jax.lax.top_k(probs, k)
    gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
    y_ref = np.zeros((t, d), np.float32)
    for ti in range(t):
        for kk in range(k):
            ei = int(idx[ti, kk])
            h = jax.nn.silu(x[ti] @ p["w_gate"][ei]) * \
                (x[ti] @ p["w_up"][ei])
            y_ref[ti] += float(gv[ti, kk]) * np.asarray(h @ p["w_down"][ei])
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-5)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2 ** 16),
    rows=st.sampled_from([8, 64, 100]),
    cols=st.sampled_from([128, 300]),
)
def test_init_prng_fabric_equivalence(seed, rows, cols):
    """Init pseudo-protocol PRNG: RTL byte stream == Pallas kernel words,
    for any seed and tile shape."""
    from repro.core import InitPattern, init_stream
    from repro.kernels.init_engine import prng_fill
    words = prng_fill((rows, cols), seed, jnp.uint32, backend="pallas",
                      interpret=True)
    rtl = init_stream(InitPattern.PSEUDORANDOM, seed, 0, rows * cols * 4)
    assert np.array_equal(
        np.asarray(words).reshape(-1).view(np.uint8), rtl)
