"""Distribution tests (subprocess, 8 fake devices): sharded == unsharded,
pipeline parallelism, compressed psum, collective plans."""

import pytest

from repro.dist.collectives import (allreduce_cycles, allreduce_seconds,
                                    alltoall_plan, ring_allreduce_plan)
from repro.dist.pipeline_parallel import pipeline_bubble
from repro.dist.sharding import spec_for_path


class TestParamRules:
    @pytest.mark.parametrize("path,ndim,want", [
        ("segments/0/0/attn/wq/kernel", 3, (None, None, "model")),
        ("segments/0/0/attn/wo/kernel", 3, (None, "model", None)),
        ("segments/0/0/ffn/w_gate/kernel", 3, (None, None, "model")),
        ("segments/0/0/ffn/w_down/kernel", 3, (None, "model", None)),
        ("segments/0/0/moe/w_gate", 4, (None, None, None, "model")),
        ("segments/0/0/moe/w_down", 4, (None, None, "model", None)),
        ("embed/table", 2, ("model", None)),
        ("segments/0/0/ssm/in_proj/kernel", 3, (None, None, "model")),
        ("segments/0/0/ln1/scale", 2, (None, None)),
    ])
    def test_rules(self, path, ndim, want):
        spec = spec_for_path(path, ndim)
        got = tuple(spec) + (None,) * (ndim - len(tuple(spec)))
        assert got == tuple(want), f"{path}: {got}"


class TestCollectivePlans:
    def test_ring_allreduce_volume(self):
        steps = ring_allreduce_plan(1 << 20, 8)
        assert len(steps) == 14            # 2*(8-1)
        per_step = sum(t.length for t in steps[0])
        assert per_step == (1 << 20) // 8

    def test_allreduce_cycles_scale(self):
        c1 = allreduce_cycles(1 << 20, 8)
        c2 = allreduce_cycles(2 << 20, 8)
        assert 1.8 < c2 / c1 < 2.2
        assert allreduce_seconds(1 << 20, 8) > 0

    def test_alltoall_ports(self):
        ports = alltoall_plan(1 << 16, 8)
        assert len(ports) == 4
        total = sum(t.length for p in ports for t in p)
        assert total == (1 << 16) * 7


def test_pipeline_bubble():
    assert pipeline_bubble(4, 12) == pytest.approx(3 / 15)


class TestMultiDevice:
    def test_sharded_train_step_matches_single_device(self, subproc):
        out = subproc("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.configs import get
            from repro.configs.base import RunConfig, reduced
            from repro.train.train_step import init_train_state, make_train_step
            from repro.dist import sharding as shd

            cfg = reduced(get("internlm2-20b"), n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)
            rcfg = RunConfig(kernels="xla", dtype="float32", remat=False,
                             learning_rate=1e-3)
            key = jax.random.PRNGKey(0)
            state = init_train_state(key, cfg)
            batch = {"tokens": jax.random.randint(key, (8, 32), 0, 256)}
            step = make_train_step(cfg, rcfg)

            # single device reference
            s_ref, m_ref = jax.jit(step)(state, batch)

            mesh = jax.make_mesh((4, 2), ("data", "model"))
            st_sh = {
                "params": shd.param_shardings(state["params"], mesh),
                "opt": {"mu": shd.param_shardings(state["params"], mesh),
                        "nu": shd.param_shardings(state["params"], mesh),
                        "count": NamedSharding(mesh, P())},
                "step": NamedSharding(mesh, P()),
            }
            b_sh = {"tokens": NamedSharding(mesh, P("data", None))}
            with mesh:
                s_d, m_d = jax.jit(step, in_shardings=(st_sh, b_sh),
                                   out_shardings=(st_sh, None))(state, batch)
            np.testing.assert_allclose(float(m_ref["loss"]),
                                       float(m_d["loss"]), rtol=1e-5)
            for a, b in zip(jax.tree_util.tree_leaves(s_ref["params"]),
                            jax.tree_util.tree_leaves(s_d["params"])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=5e-4, atol=1e-5)
            print("SHARDED_MATCH_OK")
        """, n_devices=8)
        assert "SHARDED_MATCH_OK" in out

    def test_moe_shard_map_matches_local(self, subproc):
        out = subproc("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get
            from repro.configs.base import RunConfig, reduced
            from repro.models import init_lm, lm_loss
            from repro.dist import sharding as shd

            cfg = reduced(get("mixtral-8x7b"), n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)
            rcfg = RunConfig(kernels="xla", dtype="float32", remat=False)
            key = jax.random.PRNGKey(1)
            params = init_lm(key, cfg)
            batch = {"tokens": jax.random.randint(key, (8, 16), 0, 256)}
            loss_local, _ = jax.jit(
                lambda p, b: lm_loss(p, b, cfg, rcfg))(params, batch)

            mesh = jax.make_mesh((4, 2), ("data", "model"))
            shd.set_moe_mesh(mesh)
            with mesh:
                loss_dist, _ = jax.jit(
                    lambda p, b: lm_loss(p, b, cfg, rcfg))(params, batch)
            shd.set_moe_mesh(None)
            np.testing.assert_allclose(float(loss_local), float(loss_dist),
                                       rtol=2e-4)
            print("MOE_SHARDMAP_OK")
        """, n_devices=8)
        assert "MOE_SHARDMAP_OK" in out

    def test_gpipe_matches_sequential(self, subproc):
        out = subproc("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.dist.pipeline_parallel import gpipe, stack_stage_params

            mesh = jax.make_mesh((4,), ("stage",))
            key = jax.random.PRNGKey(0)
            D = 16
            ws = [jax.random.normal(jax.random.fold_in(key, i), (D, D)) * 0.3
                  for i in range(4)]

            def stage_fn(w, x):
                return jnp.tanh(x @ w)

            stage_params = stack_stage_params(ws)
            M, mb = 8, 4
            x = jax.random.normal(key, (M, mb, D))
            # sequential reference
            ref = x
            for w in ws:
                ref = jnp.tanh(ref @ w)
            with mesh:
                piped = jax.jit(gpipe(stage_fn, mesh, "stage"))(
                    stage_params, x)
            np.testing.assert_allclose(np.asarray(piped), np.asarray(ref),
                                       rtol=1e-5, atol=1e-6)
            print("GPIPE_OK")
        """, n_devices=4)
        assert "GPIPE_OK" in out

    def test_compressed_psum_close_to_exact(self, subproc):
        out = subproc("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.dist.collectives import compressed_psum

            mesh = jax.make_mesh((8,), ("data",))
            x = jax.random.normal(jax.random.PRNGKey(0), (8, 256))

            def f(xl):
                return compressed_psum(xl[0], "data")

            with mesh:
                approx = shard_map(f, mesh=mesh, in_specs=(P("data", None),),
                                   out_specs=P(), check_rep=False)(x)
            exact = jnp.sum(x, axis=0)
            rel = float(jnp.max(jnp.abs(approx - exact)) /
                        jnp.max(jnp.abs(exact)))
            assert rel < 0.1, rel
            print("CPSUM_OK", rel)
        """, n_devices=8)
        assert "CPSUM_OK" in out
