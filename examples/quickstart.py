"""Quickstart: the iDMA engine end-to-end in five minutes.

1. Compose an engine from an `EngineSpec` (front-end × mid-end ×
   back-end), program a 3-D transfer through its register front-end and
   watch the bytes move (functional back-end).
2. Simulate the same transfer on the cycle-accurate transport model.
3. Run the same descriptor plan as a Pallas copy kernel (interpret mode).
4. Fill memory with the Init pseudo-protocol on both fabrics.
5. Hide deep-memory latency with outstanding transfers (single channel).
6. Overlap latency with *concurrent channels* sharing one endpoint — the
   asynchronous submit/poll/wait control plane.
7. Instantiate the paper's named presets and a custom plan-cached
   mid-end pipeline (split → dist) — the composable instantiation API.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import (HBM, BackendSpec, ChannelSpec, EngineConfig,
                        EngineSpec, FrontendSpec, InitPattern, MemoryMap,
                        MpDistStage, MpSplitStage, NdTransfer, Protocol,
                        TensorDim, Transfer1D, build_engine, build_frontend,
                        make_fragmented_batch, plan_nd_copy, preset,
                        simulate, simulate_channels)
from repro.core.analytics import plan_cache_profile
from repro.core.descriptor import BackendOptions


def main() -> None:
    # -- 1. compose + run: a strided 3-D gather ----------------------------
    spec = EngineSpec(
        name="quickstart",
        frontend=FrontendSpec(kind="reg", word_bits=32, ndims=3),
        backend=BackendSpec(bus_width=8,
                            protocols=(Protocol.AXI4, Protocol.OBI)),
        mem_spaces=((Protocol.AXI4, 1 << 16), (Protocol.OBI, 1 << 16)),
    )
    engine = build_engine(spec)
    mem = engine.mem
    src = np.arange(4096, dtype=np.uint8)
    mem.spaces[Protocol.AXI4][:4096] = src

    fe = build_frontend(spec, engine)
    fe.configure(src=0, dst=0, length=64,
                 dims=(TensorDim(src_stride=128, dst_stride=64, reps=8),),
                 src_protocol=Protocol.AXI4, dst_protocol=Protocol.OBI)
    tid = fe.launch()
    got = mem.spaces[Protocol.OBI][:512]
    want = np.concatenate([src[i * 128:i * 128 + 64] for i in range(8)])
    assert np.array_equal(got, want)
    print(f"[1] reg_32_3d transfer #{tid}: 8x64B strided gather OK "
          f"({engine.stats.bursts} legalized bursts)")

    # -- 2. cycle model: how long would this take? -------------------------
    res = engine.simulate(NdTransfer(
        0, 0, 64, (TensorDim(128, 64, 8),), Protocol.AXI4, Protocol.OBI))
    print(f"[2] transport model: {res.cycles} cycles, "
          f"first read request at cycle {res.first_read_req} "
          f"(paper: 2), bus utilization {res.utilization:.2f}")

    # -- 3. same plan on the TPU fabric (Pallas interpret mode) ------------
    from repro.kernels.copy_engine import copy_2d
    plan = plan_nd_copy((512, 1024), 4, n_buffers=2)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((512, 1024)),
                    jnp.float32)
    y = copy_2d(x, backend="pallas", interpret=True)
    assert np.allclose(y, x)
    print(f"[3] Pallas copy engine: tile {plan.tile}, grid {plan.grid}, "
          f"VMEM {plan.vmem_bytes // 1024} KiB ({plan.n_buffers} buffers)")

    # -- 4. Init pseudo-protocol on both fabrics ---------------------------
    opts = BackendOptions(init_pattern=InitPattern.PSEUDORANDOM,
                          init_value=42)
    engine.submit(Transfer1D(0, 0, 512, Protocol.INIT, Protocol.OBI,
                             options=opts))
    from repro.kernels.init_engine import prng_fill
    kernel_words = prng_fill((8, 16), 42, jnp.uint32, backend="pallas",
                             interpret=True)
    rtl_bytes = mem.spaces[Protocol.OBI][:512]
    assert np.array_equal(
        np.asarray(kernel_words).reshape(-1).view(np.uint8), rtl_bytes)
    print("[4] Init PRNG: RTL byte stream == Pallas kernel stream (512 B)")

    # -- bonus: deep-memory latency hiding (the paper's headline) ----------
    cfg = EngineConfig(bus_width=4, n_outstanding=64)
    ts = [Transfer1D(i * 16, i * 16, 16) for i in range(4096)]
    r = simulate(ts, cfg, HBM, HBM)
    print(f"[5] 16B transfers @ 100-cycle HBM latency: "
          f"{r.utilization:.1%} bus utilization (paper: ~100%)")

    # -- 6. concurrent channels + the async control plane ------------------
    shallow = EngineConfig(bus_width=4, n_outstanding=2)
    bw = {}
    for n in (1, 4):
        batches = [make_fragmented_batch(64 * 1024 // n, 16)
                   for _ in range(n)]
        bw[n] = simulate_channels(batches, shallow,
                                  (HBM, HBM)).aggregate_bandwidth
    print(f"[6] shared-HBM concurrency: 1 ch {bw[1]:.2f} B/cyc -> "
          f"4 ch {bw[4]:.2f} B/cyc ({bw[4] / bw[1]:.1f}x aggregate)")

    multi = build_engine(
        EngineSpec(name="quickstart_multi", channels=ChannelSpec(count=4)),
        mem=mem)
    tids = [multi.submit_async(Transfer1D(i * 256, 4096 + i * 256, 256,
                                          Protocol.AXI4, Protocol.OBI))
            for i in range(8)]
    assert all(multi.poll(t) == "pending" for t in tids)
    res = multi.wait_all()
    assert all(multi.poll(t) == "done" for t in tids)
    print(f"[6] async submit x{len(tids)} over "
          f"{len(res.per_channel)} channels: drained in "
          f"{res.aggregate.cycles} modeled cycles")

    # -- 7. the composable instantiation API -------------------------------
    # the paper's instantiation matrix (§3) as one-call presets:
    for name in ("pulp_cluster", "manticore", "cheshire", "edge_ai"):
        s = preset(name)
        e = build_engine(s)
        r = e.simulate(Transfer1D(0, 1 << 12, 4096,
                                  src_protocol=s.backend.protocols[0],
                                  dst_protocol=s.backend.protocols[-1]))
        print(f"[7] preset {name:12s} ({s.frontend.name} front-end, "
              f"{s.backend.bus_width * 8}-b bus, {s.channels.count} ch): "
              f"4 KiB in {r.cycles} cycles @ "
              f"{s.src_system.name}->{s.dst_system.name}")

    # a custom mid-end pipeline (MemPool-style split -> dist) stays on the
    # vectorized path AND plan-caches: repeated structurally identical
    # submissions replay a captured plan (watch the hit counter)
    custom = build_engine(EngineSpec(
        name="split_dist",
        midend=(MpSplitStage(boundary=256),
                MpDistStage(num_ports=2, boundary=256)),
        plan_cache=16,
        mem_spaces=((Protocol.AXI4, 1 << 16),),
    ))
    for step in range(4):
        custom.submit(Transfer1D(0, 4096 + step * 4096, 1024))
    prof = plan_cache_profile(custom.plan_cache)
    assert prof["hits"] == 3 and prof["misses"] == 1
    print(f"[7] custom split->dist pipeline: plan cache "
          f"{prof['hits']} hits / {prof['misses']} miss over 4 doorbells "
          f"({custom.stats.bursts} bursts stayed on the batch path)")


if __name__ == "__main__":
    main()
