"""Quickstart: the iDMA engine end-to-end in five minutes.

1. Program a 3-D transfer through the register front-end and watch the
   bytes move (functional back-end).
2. Simulate the same transfer on the cycle-accurate transport model.
3. Run the same descriptor plan as a Pallas copy kernel (interpret mode).
4. Fill memory with the Init pseudo-protocol on both fabrics.
5. Hide deep-memory latency with outstanding transfers (single channel).
6. Overlap latency with *concurrent channels* sharing one endpoint — the
   asynchronous submit/poll/wait control plane.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import (HBM, EngineConfig, IDMAEngine, InitPattern,
                        MemoryMap, NdTransfer, Protocol, RegFrontend,
                        TensorDim, Transfer1D, make_fragmented_batch,
                        plan_nd_copy, simulate, simulate_channels)
from repro.core.descriptor import BackendOptions


def main() -> None:
    # -- 1. functional engine: a strided 3-D gather ------------------------
    mem = MemoryMap.create({Protocol.AXI4: 1 << 16, Protocol.OBI: 1 << 16})
    engine = IDMAEngine(mem=mem)
    src = np.arange(4096, dtype=np.uint8)
    mem.spaces[Protocol.AXI4][:4096] = src

    fe = RegFrontend(engine, word_bits=32, ndims=3)
    fe.configure(src=0, dst=0, length=64,
                 dims=(TensorDim(src_stride=128, dst_stride=64, reps=8),),
                 src_protocol=Protocol.AXI4, dst_protocol=Protocol.OBI)
    tid = fe.launch()
    got = mem.spaces[Protocol.OBI][:512]
    want = np.concatenate([src[i * 128:i * 128 + 64] for i in range(8)])
    assert np.array_equal(got, want)
    print(f"[1] reg_32_3d transfer #{tid}: 8x64B strided gather OK "
          f"({engine.stats.bursts} legalized bursts)")

    # -- 2. cycle model: how long would this take? -------------------------
    res = engine.simulate(NdTransfer(
        0, 0, 64, (TensorDim(128, 64, 8),), Protocol.AXI4, Protocol.OBI))
    print(f"[2] transport model: {res.cycles} cycles, "
          f"first read request at cycle {res.first_read_req} "
          f"(paper: 2), bus utilization {res.utilization:.2f}")

    # -- 3. same plan on the TPU fabric (Pallas interpret mode) ------------
    from repro.kernels.copy_engine import copy_2d
    plan = plan_nd_copy((512, 1024), 4, n_buffers=2)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((512, 1024)),
                    jnp.float32)
    y = copy_2d(x, backend="pallas", interpret=True)
    assert np.allclose(y, x)
    print(f"[3] Pallas copy engine: tile {plan.tile}, grid {plan.grid}, "
          f"VMEM {plan.vmem_bytes // 1024} KiB ({plan.n_buffers} buffers)")

    # -- 4. Init pseudo-protocol on both fabrics ---------------------------
    opts = BackendOptions(init_pattern=InitPattern.PSEUDORANDOM,
                          init_value=42)
    engine.submit(Transfer1D(0, 0, 512, Protocol.INIT, Protocol.OBI,
                             options=opts))
    from repro.kernels.init_engine import prng_fill
    kernel_words = prng_fill((8, 16), 42, jnp.uint32, backend="pallas",
                             interpret=True)
    rtl_bytes = mem.spaces[Protocol.OBI][:512]
    assert np.array_equal(
        np.asarray(kernel_words).reshape(-1).view(np.uint8), rtl_bytes)
    print("[4] Init PRNG: RTL byte stream == Pallas kernel stream (512 B)")

    # -- bonus: deep-memory latency hiding (the paper's headline) ----------
    cfg = EngineConfig(bus_width=4, n_outstanding=64)
    ts = [Transfer1D(i * 16, i * 16, 16) for i in range(4096)]
    r = simulate(ts, cfg, HBM, HBM)
    print(f"[5] 16B transfers @ 100-cycle HBM latency: "
          f"{r.utilization:.1%} bus utilization (paper: ~100%)")

    # -- 6. concurrent channels + the async control plane ------------------
    shallow = EngineConfig(bus_width=4, n_outstanding=2)
    bw = {}
    for n in (1, 4):
        batches = [make_fragmented_batch(64 * 1024 // n, 16)
                   for _ in range(n)]
        bw[n] = simulate_channels(batches, shallow,
                                  (HBM, HBM)).aggregate_bandwidth
    print(f"[6] shared-HBM concurrency: 1 ch {bw[1]:.2f} B/cyc -> "
          f"4 ch {bw[4]:.2f} B/cyc ({bw[4] / bw[1]:.1f}x aggregate)")

    multi = IDMAEngine(mem=mem, num_channels=4)
    tids = [multi.submit_async(Transfer1D(i * 256, 4096 + i * 256, 256,
                                          Protocol.AXI4, Protocol.OBI))
            for i in range(8)]
    assert all(multi.poll(t) == "pending" for t in tids)
    res = multi.wait_all()
    assert all(multi.poll(t) == "done" for t in tids)
    print(f"[6] async submit x{len(tids)} over "
          f"{len(res.per_channel)} channels: drained in "
          f"{res.aggregate.cycles} modeled cycles")


if __name__ == "__main__":
    main()
