"""Memory initialization with the Init pseudo-protocol — the paper's
lightweight data-initialization feature (Table 3) on both fabrics, plus a
KV-cache page-pool zeroing demo.

    PYTHONPATH=src python examples/memset_init.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import (IDMAEngine, InitPattern, MemoryMap, Protocol,
                        Transfer1D)
from repro.core.descriptor import BackendOptions
from repro.kernels.init_engine import iota_fill, memset, prng_fill
from repro.serve.kvcache import PagePool, init_paged_kv, make_page_tables


def main() -> None:
    # RTL fabric: init an 8 KiB region three ways
    mem = MemoryMap.create({Protocol.OBI: 1 << 16})
    eng = IDMAEngine(mem=mem)
    for pattern, value in [(InitPattern.CONSTANT, 0),
                           (InitPattern.INCREMENTING, 5),
                           (InitPattern.PSEUDORANDOM, 123)]:
        opts = BackendOptions(init_pattern=pattern, init_value=value)
        eng.submit(Transfer1D(0, 0, 8192, Protocol.INIT, Protocol.OBI,
                              options=opts))
        print(f"init {pattern.value:14s} first bytes:",
              mem.spaces[Protocol.OBI][:8].tolist())

    # TPU fabric: the same generators as Pallas kernels
    z = memset((256, 512), 0.0, backend="pallas", interpret=True)
    i = iota_fill((8, 128), 100, backend="pallas", interpret=True)
    r = prng_fill((8, 128), 123, jnp.float32, backend="pallas",
                  interpret=True)
    print("kernel memset sum:", float(z.sum()),
          "| iota[0,:4]:", np.asarray(i)[0, :4].tolist(),
          "| prng mean:", round(float(r.mean()), 3), "(~0.5)")

    # Framework use: zero-filled KV pages on allocation
    pool_alloc = PagePool(n_pages=64, page_size=16)
    pool = init_paged_kv(64, 16, n_kv_heads=2, dh=64)
    tables = make_page_tables(pool_alloc, batch=2, seq_len=128)
    print(f"KV pool: {pool['k'].shape} pages zero-initialized, "
          f"{len(pool_alloc.free)} pages free after 2x128-token alloc")


if __name__ == "__main__":
    main()
