"""End-to-end training driver: a reduced gemma2-family model trained for a
few hundred steps on the deterministic synthetic pipeline, with
checkpointing and an injected mid-run node failure that the trainer
recovers from (error-handler 'replay' semantics).

    PYTHONPATH=src python examples/train_tinylm.py [--steps 200]
"""

import argparse
import json
import tempfile
import time

from repro.configs import get
from repro.configs.base import RunConfig, reduced
from repro.dist.fault import FaultConfig, FaultInjector
from repro.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="gemma2-2b")
    args = ap.parse_args()

    cfg = reduced(get(args.arch), n_layers=4, d_model=128, n_heads=4,
                  n_kv_heads=2, d_ff=512, vocab=2048)
    rcfg = RunConfig(kernels="xla", dtype="float32", remat=False,
                     learning_rate=1e-3)
    ckpt_dir = tempfile.mkdtemp(prefix="tinylm_ckpt_")
    tcfg = TrainerConfig(total_steps=args.steps,
                         checkpoint_every=max(args.steps // 4, 10),
                         checkpoint_dir=ckpt_dir,
                         fault=FaultConfig(policy="replay"))
    injector = FaultInjector(fail_steps=[args.steps // 2], kind="node")
    trainer = Trainer(cfg, rcfg, tcfg, seq_len=128, global_batch=8,
                      injector=injector)

    t0 = time.time()
    state = trainer.run()
    dt = time.time() - t0
    losses = [h["loss"] for h in trainer.history]
    print(json.dumps({
        "arch": cfg.name,
        "steps": int(state["step"]),
        "first_loss": round(losses[0], 4),
        "last_loss": round(losses[-1], 4),
        "node_failures_recovered": trainer.stats.node_failures,
        "wall_s": round(dt, 1),
        "steps_per_s": round(len(losses) / dt, 2),
        "checkpoints": ckpt_dir,
    }, indent=1))
    assert trainer.stats.node_failures == 1, "fault injection did not fire"
    assert int(state["step"]) == args.steps, "did not reach target step"
    # uniform-random synthetic tokens sit at ln(vocab) from step 0; check
    # the loop stayed at the optimum rather than diverging
    import math
    assert abs(losses[-1] - math.log(cfg.vocab_size)) < 0.5, losses[-1]


if __name__ == "__main__":
    main()
