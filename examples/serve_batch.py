"""Batched serving example: prefill + ring-buffer decode with greedy
sampling over a mixed batch of requests, on a reduced mixtral-family model.

    PYTHONPATH=src python examples/serve_batch.py
"""

import json
import time

import jax
import numpy as np

from repro.configs import get
from repro.configs.base import RunConfig, reduced
from repro.models import init_lm
from repro.serve import Request, ServeEngine


def main() -> None:
    cfg = reduced(get("mixtral-8x7b"), n_layers=2, d_model=128, n_heads=4,
                  n_kv_heads=2, d_ff=256, vocab=1024)
    rcfg = RunConfig(kernels="xla", dtype="float32", remat=False)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, rcfg, params, max_len=128)

    rng = np.random.default_rng(7)
    prompts = [list(rng.integers(0, cfg.vocab_size, 24)) for _ in range(4)]
    reqs = [Request(prompt=p, max_new_tokens=12) for p in prompts]

    t0 = time.time()
    engine.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.output) for r in reqs)
    print(json.dumps({
        "arch": cfg.name,
        "batch": len(reqs),
        "generated_tokens": total,
        "tok_per_s": round(total / dt, 1),
        "outputs": [r.output[:6] for r in reqs],
    }, indent=1))
    # determinism check: greedy decode twice gives identical streams
    reqs2 = [Request(prompt=p, max_new_tokens=12) for p in prompts]
    engine.generate(reqs2)
    assert all(a.output == b.output for a, b in zip(reqs, reqs2))
    print("deterministic: OK")


if __name__ == "__main__":
    main()
