"""Fig. 14 reproduction: bus utilization vs transfer size for the three
memory systems (SRAM / RPC-DRAM / HBM) at increasing outstanding-transfer
counts — 32-b base configuration, 64 KiB total.

The fragmented descriptor stream of each sweep cell is built once as a
`DescriptorBatch` per fragment size and re-simulated across all (memory
system, NAx) points — the batch is immutable, so the 11x3xN sweep never
re-materializes descriptors."""

from __future__ import annotations

from repro.core import (HBM, RPC_DRAM, SRAM, EngineConfig,
                        make_fragmented_batch, simulate_batch)

SYSTEMS = [SRAM, RPC_DRAM, HBM]
NAX = [2, 4, 8, 16, 32, 64]
FRAGS = [4, 8, 16, 32, 64, 128, 256, 1024]
TOTAL = 64 * 1024


def run(csv_rows):
    batches = {frag: make_fragmented_batch(TOTAL, frag) for frag in FRAGS}
    for mem in SYSTEMS:
        for nax in NAX:
            cfg = EngineConfig(bus_width=4, n_outstanding=nax)
            for frag in FRAGS:
                res = simulate_batch(batches[frag], cfg, mem, mem)
                csv_rows.append(
                    (f"fig14_{mem.name}_nax{nax}_{frag}B",
                     res.utilization, ""))
    # §4.4 headline: 4x bus width reaches ~full utilization even at depth
    cfg = EngineConfig(bus_width=4, n_outstanding=64)
    u16 = simulate_batch(make_fragmented_batch(TOTAL, 16), cfg,
                         HBM, HBM).utilization
    csv_rows.append(("fig14_HBM_16B_nax64", u16, "paper=~1.0"))
