"""Fig. 14 reproduction: bus utilization vs transfer size for the three
memory systems (SRAM / RPC-DRAM / HBM) at increasing outstanding-transfer
counts — 32-b base configuration, 64 KiB total."""

from __future__ import annotations

from repro.core import (HBM, RPC_DRAM, SRAM, EngineConfig,
                        utilization_sweep)

SYSTEMS = [SRAM, RPC_DRAM, HBM]
NAX = [2, 4, 8, 16, 32, 64]
FRAGS = [4, 8, 16, 32, 64, 128, 256, 1024]


def run(csv_rows):
    for mem in SYSTEMS:
        for nax in NAX:
            cfg = EngineConfig(bus_width=4, n_outstanding=nax)
            util = utilization_sweep(cfg, mem, fragments=FRAGS)
            for frag, u in util.items():
                csv_rows.append(
                    (f"fig14_{mem.name}_nax{nax}_{frag}B", u, ""))
    # §4.4 headline: 4x bus width reaches ~full utilization even at depth
    cfg = EngineConfig(bus_width=4, n_outstanding=64)
    u16 = utilization_sweep(cfg, HBM, fragments=(16,))[16]
    csv_rows.append(("fig14_HBM_16B_nax64", u16, "paper=~1.0"))
