"""Virtual-memory translation overhead: translated vs physical gather.

A 1M-burst sparse gather (page-random 64-byte rows, the MoE
expert-routing access shape) is dispatched twice through the same
engine composition:

* **physical** — no mid-end: addresses are already physical;
* **translated** — the same rows submitted by *virtual* address through
  a `TranslateStage` over an identity page table (vpn == ppn), so both
  paths execute byte-identical burst streams and the wall-clock delta
  is purely the vectorized page split + TLB-cached table walk.

Both engines run with the plan cache on and are warmed with one
untimed drain first (plan captured, TLB populated), so the timed loop
measures the steady state: a plan rebind plus — on the translated
path — the per-drain revalidating VA→PA rebind.  Rows never cross a
page boundary, so the lowered streams (and burst counts) are identical.
The gate asserts the translated path stays within **1.3x** of the
physical one and that the final memory images match byte for byte.

Results land in ``LAST`` for ``benchmarks/run.py --json`` snapshots.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import DescriptorBatch, Protocol, build_engine
from repro.core.spec import BackendSpec, ChannelSpec, EngineSpec
from repro.core.vm import PageTable, TranslateStage

PAGE = 4096
N_BURSTS = 1 << 20           # 1M gather rows
ROW_BYTES = 64
SRC_PAGES = 8192             # 32 MiB gather source region
GATE = 1.3
REPEATS = 3
#: --quick smoke sizes: 128k bursts, one repeat, a looser gate (the
#: fixed per-drain overheads loom larger on a small timed region)
QUICK_BURSTS = 1 << 17
QUICK_GATE = 1.6

#: last run's headline numbers, for `benchmarks.run --json`
LAST = {}


def _gather_batch(n_bursts: int, seed: int = 0) -> DescriptorBatch:
    """Page-random aligned 64-byte gather rows with a dense destination
    (the translated twin of an expert-routing gather); rows never cross
    a page boundary."""
    rng = np.random.default_rng(seed)
    src_page = rng.integers(0, SRC_PAGES, size=n_bursts, dtype=np.int64)
    src_slot = rng.integers(0, PAGE // ROW_BYTES, size=n_bursts,
                            dtype=np.int64)
    src = src_page * PAGE + src_slot * ROW_BYTES
    dst = SRC_PAGES * PAGE + \
        np.arange(n_bursts, dtype=np.int64) * ROW_BYTES
    return DescriptorBatch.from_arrays(
        src_addr=src, dst_addr=dst,
        length=np.full(n_bursts, ROW_BYTES, dtype=np.int64))


def _build(translated: bool, n_pages: int):
    """Engine + (for the translated path) its live translate stage."""
    midend = ()
    stage = None
    if translated:
        table = PageTable({Protocol.AXI4: PAGE})
        table.map_range(Protocol.AXI4, 0, 0, n_pages)   # identity map
        # size the TLB to the working set (src + dst pages): after the
        # warm drain the timed loop runs fully TLB-resident
        stage = TranslateStage(table, tlb_capacity=1 << 15)
        midend = (stage,)
    spec = EngineSpec(
        name="vm_translate" if translated else "vm_physical",
        midend=midend,
        backend=BackendSpec(protocols=(Protocol.AXI4,), bus_width=8),
        channels=ChannelSpec(count=1),
        mem_spaces=((Protocol.AXI4, n_pages * PAGE),))
    engine = build_engine(spec, plan_cache=4)
    rng = np.random.default_rng(7)
    buf = engine.mem.spaces[Protocol.AXI4]
    buf[:] = rng.integers(0, 256, size=buf.size, dtype=np.uint8)
    return engine, stage


def _drain(engine, batch) -> float:
    t0 = time.perf_counter()
    engine.dispatch_batch(batch)
    engine.wait_all()
    return time.perf_counter() - t0


def run(csv_rows, quick: bool = False):
    n_bursts = QUICK_BURSTS if quick else N_BURSTS
    repeats = 1 if quick else REPEATS
    gate = QUICK_GATE if quick else GATE
    n_pages = SRC_PAGES + (n_bursts * ROW_BYTES) // PAGE
    batch = _gather_batch(n_bursts)
    eng_p, _ = _build(translated=False, n_pages=n_pages)
    eng_v, stage = _build(translated=True, n_pages=n_pages)

    _drain(eng_p, batch)         # warm: plan captured
    _drain(eng_v, batch)         # warm: plan captured + TLB populated

    t_phys = t_virt = float("inf")
    for _ in range(repeats):
        t_phys = min(t_phys, _drain(eng_p, batch))
        t_virt = min(t_virt, _drain(eng_v, batch))

    # identity mapping => byte-identical images, and equal burst counts
    a = eng_p.mem.spaces[Protocol.AXI4]
    b = eng_v.mem.spaces[Protocol.AXI4]
    assert np.array_equal(a, b), \
        "translated gather diverged from the physical path"
    assert eng_p.stats.bursts == eng_v.stats.bursts

    ratio = t_virt / t_phys
    ts = stage.tlb.stats
    looked = ts.hits + ts.misses
    hit_rate = ts.hits / looked if looked else 0.0
    csv_rows.append(("vm_translate_bursts", n_bursts, ""))
    csv_rows.append(("vm_translate_physical_s", t_phys, ""))
    csv_rows.append(("vm_translate_translated_s", t_virt, ""))
    csv_rows.append(("vm_translate_ratio", ratio, f"target<={gate:g}x"))
    csv_rows.append(("vm_translate_tlb_hit_rate", hit_rate, ""))

    LAST.update({
        "bursts": n_bursts,
        "row_bytes": ROW_BYTES,
        "page_bytes": PAGE,
        "physical_s": t_phys,
        "translated_s": t_virt,
        "ratio": ratio,
        "tlb": {"hits": ts.hits, "misses": ts.misses,
                "evictions": ts.evictions, "hit_rate": hit_rate},
    })
    assert ratio <= gate, \
        f"translated gather {ratio:.2f}x over physical (need <= {gate:g}x)"


if __name__ == "__main__":
    rows = []
    run(rows)
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")
