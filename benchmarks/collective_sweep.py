"""Collective-fabric sweep: engines x channels x message size.

The distributed layer's headline: an ML collective (ring allreduce)
lowered to `DescriptorBatch` traffic across N `IDMAEngine`s sharing one
contended HBM-class `MemSystem` scales with engine count, because each
engine's small outstanding window is latency-bound against the 100-cycle
endpoint and N engines overlap those latency windows (the same effect
`channel_sweep` shows for raw channels, here driven end-to-end through
the fabric's plan-cache lowering and interrupt-driven phase engine).

Sweeps ``ENGINES x MESSAGE_SIZES`` (plus a channel sweep at the largest
size) measuring contended makespan vs `serial_cycles` — the identical
streams re-timed back-to-back through one engine.

Gates (CI):
* multi-engine speedup >= 1.5x vs single-engine serial replay at the
  largest message size (4 engines);
* byte identity: every swept collective's result must equal the
  pure-NumPy schedule mirror bit-for-bit.

Standalone: ``PYTHONPATH=src python -m benchmarks.collective_sweep
[--json PATH]``.
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.dist.fabric import CollectiveFabric, numpy_ring_allreduce

ENGINES = (1, 2, 4)
#: message sizes in bytes per rank (float32 vectors)
MESSAGE_SIZES = (1 << 12, 1 << 14, 1 << 16, 1 << 18)
CHANNELS = (1, 2, 4)

QUICK_SIZES = (1 << 12, 1 << 14)

#: last run's headline numbers, for `benchmarks.run --json`
LAST = {}


def _shards(world: int, nbytes: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(nbytes // 4).astype(np.float32)
            for _ in range(world)]


def sweep(engines=ENGINES, sizes=MESSAGE_SIZES, channels: int = 1):
    """{(world, nbytes): dict} — contended cycles, serial-replay cycles,
    speedup, bytes moved; results byte-checked against the NumPy mirror
    on every cell."""
    out = {}
    for world in engines:
        for nbytes in sizes:
            region = max(1 << 16, 4 * nbytes)
            fab = CollectiveFabric(world, region_bytes=region,
                                   channels=channels)
            shards = _shards(world, nbytes)
            result, trace = fab.allreduce(shards)
            ref = numpy_ring_allreduce(shards)
            for got, want in zip(result, ref):
                assert got.tobytes() == want.tobytes(), \
                    f"byte mismatch: world={world} nbytes={nbytes}"
            serial = fab.serial_cycles(trace) if trace.phases else 0
            cycles = trace.total_cycles
            out[(world, nbytes)] = {
                "cycles": cycles,
                "serial_cycles": serial,
                "speedup": (serial / cycles) if cycles else 1.0,
                "bytes": trace.total_bytes,
                "phases": len(trace.phases),
            }
    return out


def run(csv_rows, quick: bool = False):
    sizes = QUICK_SIZES if quick else MESSAGE_SIZES
    cells = sweep(sizes=sizes)
    table = {}
    for (world, nbytes), m in sorted(cells.items()):
        table[f"{world}eng_{nbytes}B"] = m
        csv_rows.append((f"coll_{world}eng_{nbytes}B_cycles",
                         m["cycles"], "contended makespan"))
        if world > 1:
            csv_rows.append((f"coll_{world}eng_{nbytes}B_speedup",
                             m["speedup"], "vs serial replay"))

    # channel sweep at the largest size, 4 engines
    largest = sizes[-1]
    chan_speedups = {}
    for ch in CHANNELS:
        m = sweep(engines=(4,), sizes=(largest,), channels=ch)[(4, largest)]
        chan_speedups[ch] = m["speedup"]
        csv_rows.append((f"coll_4eng_{ch}ch_{largest}B_speedup",
                         m["speedup"], "vs serial replay"))

    top = {w: cells[(w, largest)]["speedup"] for w in ENGINES if w > 1}
    LAST.update({
        "table": table,
        "channel_speedups_4eng": chan_speedups,
        "largest_message_bytes": largest,
        "speedup_at_largest": top,
        "quick": quick,
    })
    best = max(top.values())
    assert best >= 1.5, (
        f"multi-engine collective speedup only {best:.2f}x at "
        f"{largest} B (need >= 1.5x vs single-engine serial replay)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_collective_sweep.json",
                    default=None, metavar="PATH")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    rows = []
    run(rows, quick=args.quick)
    print("name,value,derived")
    for name, value, derived in rows:
        if isinstance(value, float):
            value = f"{value:.6g}"
        print(f"{name},{value},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(LAST, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
