"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived`` CSV.  `python -m benchmarks.run [--only X]`.

Suites are imported lazily so `--only` works even when a heavyweight or
optional dependency of an unrelated suite (jax, repro.dist) is missing.

``--engine <preset>`` sweeps a named `EngineSpec` preset
(`repro.core.spec.PRESETS`: pulp_cluster / manticore / cheshire /
edge_ai) through every suite whose ``run`` accepts an ``engine`` kwarg —
the suite re-runs its measurement on the preset's bundled timing models
(`channel_sweep` is the first adopter).

`--json [PATH]` additionally writes the descriptor-plane perf headline
(object-vs-batch speedup, sweep wall clocks) plus per-suite wall-clock
timings to PATH (default ``BENCH_descriptor_plane.json``), and — unless
``--no-snapshot`` — a numbered ``BENCH_<n>.json`` snapshot at the repo
root (schema: suite name → that suite's ``LAST`` metrics dict, plus a
``_meta`` record) so the perf trajectory is tracked across PRs.  ``<n>``
auto-increments past the highest existing snapshot; pin it with
``--snapshot N``.  Partial runs (``--only``) skip the numbered snapshot
unless an index is pinned explicitly.
"""

from __future__ import annotations

import argparse
import importlib
import inspect
import json
import os
import re
import sys
import time

SUITES = [
    ("bus_utilization", "Fig. 8 + §3.1"),
    ("outstanding_sweep", "Fig. 14"),
    ("area_model", "Table 4 / Fig. 12"),
    ("timing_model", "Fig. 13"),
    ("latency", "§4.3"),
    ("workload_speedup", "§3.4 / §3.5 (Fig. 11)"),
    ("descriptor_plane", "SoA vs object descriptor hot path"),
    ("dataplane", "vectorized functional data plane (execute_batch)"),
    ("sanitize", "static hazard sweep throughput vs execute_batch"),
    ("channel_sweep", "multi-channel aggregate bandwidth (§4 concurrency)"),
    ("plan_replay", "compile-once / replay-many paged-KV decode"),
    ("vm_translate", "virtual-memory translation overhead (TLB-warm)"),
    ("serve_bench", "continuous batching vs padded batch (closed loop)"),
    ("collective_sweep", "multi-engine collective fabric scaling"),
    ("kernel_bench", "kernels + TPU rooflines"),
    ("roofline", "dry-run roofline table"),
]

#: suite name → module (descriptor_plane lives in descriptor_plane_bench)
_MODULES = {name: f"benchmarks.{name}" for name, _ in SUITES}
_MODULES["descriptor_plane"] = "benchmarks.descriptor_plane_bench"
_MODULES["dataplane"] = "benchmarks.dataplane_bench"
_MODULES["sanitize"] = "benchmarks.sanitize_bench"
_MODULES["plan_replay"] = "benchmarks.plan_replay_bench"


#: repo root — numbered snapshots always land here (not the cwd), so the
#: cross-PR trajectory keeps one consistent numbering
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _next_snapshot_index(root: str = _REPO_ROOT) -> int:
    """1 + the highest existing BENCH_<n>.json index at the repo root."""
    best = 0
    for name in os.listdir(root):
        m = re.fullmatch(r"BENCH_(\d+)\.json", name)
        if m:
            best = max(best, int(m.group(1)))
    return best + 1


def write_snapshot(suite_metrics, wall, errors, index=None,
                   skipped=None) -> str:
    """Write the numbered perf-trajectory snapshot (suite → metrics)."""
    if index is None:
        index = _next_snapshot_index()
    payload = dict(suite_metrics)
    payload["_meta"] = {
        "index": index,
        "suite_wall_clock_s": wall,
        **({"suite_errors": errors} if errors else {}),
        **({"suite_skipped": skipped} if skipped else {}),
    }
    path = os.path.join(_REPO_ROOT, f"BENCH_{index}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", nargs="?", const="BENCH_descriptor_plane.json",
                    default=None, metavar="PATH",
                    help="write descriptor-plane perf + suite wall clocks")
    ap.add_argument("--snapshot", type=int, default=None, metavar="N",
                    help="pin the BENCH_<n>.json snapshot index")
    ap.add_argument("--no-snapshot", action="store_true",
                    help="skip the numbered BENCH_<n>.json snapshot")
    ap.add_argument("--engine", default=None, metavar="PRESET",
                    help="sweep a named EngineSpec preset (repro.core.spec"
                         ".PRESETS) in the suites that support it")
    ap.add_argument("--quick", action="store_true",
                    help="shrink the heavyweight suites (dataplane, "
                         "descriptor plane) to smoke-test sizes with "
                         "relaxed gates; implies --no-snapshot")
    args = ap.parse_args()

    if args.engine is not None:
        from repro.core.spec import PRESETS
        if args.engine not in PRESETS:
            ap.error(f"unknown --engine preset {args.engine!r}: expected "
                     f"one of {sorted(PRESETS)}")

    rows = []
    wall = {}
    errors = {}
    skipped = {}
    for name, what in SUITES:
        if args.only and args.only != name:
            continue
        print(f"# suite: {name} ({what})", file=sys.stderr)
        t0 = time.perf_counter()
        n_rows_before = len(rows)
        try:
            mod = importlib.import_module(_MODULES[name])
            # suites opt into preset sweeps / quick mode by kwarg
            params = inspect.signature(mod.run).parameters
            kwargs = {}
            if args.engine is not None and "engine" in params:
                kwargs["engine"] = args.engine
            if args.quick and "quick" in params:
                kwargs["quick"] = True
            mod.run(rows, **kwargs)
            wall[name] = time.perf_counter() - t0
        except ModuleNotFoundError as err:
            # a missing *optional* dependency (jax on a CPU box,
            # repro.dist before the distributed layer lands) is not a
            # broken suite: record the skip, keep the exit code green
            if args.only:
                raise
            skipped[name] = f"missing dependency: {err.name}"
            del rows[n_rows_before:]   # skipped means *no* partial rows
            print(f"# suite {name} SKIPPED ({skipped[name]})",
                  file=sys.stderr)
        except Exception as err:
            # a broken suite must not discard the rows and timings every
            # suite before it already measured
            if args.only:
                raise
            errors[name] = f"{type(err).__name__}: {err}"
            print(f"# suite {name} FAILED: {errors[name]}", file=sys.stderr)
    print("name,value,derived")
    for name, value, derived in rows:
        if isinstance(value, float):
            value = f"{value:.6g}"
        print(f"{name},{value},{derived}")

    if args.json:
        payload = {"suite_wall_clock_s": wall}
        if errors:
            payload["suite_errors"] = errors
        if skipped:
            payload["suite_skipped"] = skipped
        # persist any suite's module-level LAST dict (partial data survives
        # a failed gate; import-time failures are already in suite_errors)
        suite_metrics = {}
        for name in sorted(set(wall) | set(errors)):
            try:
                last = getattr(importlib.import_module(_MODULES[name]),
                               "LAST", None)
                if last:
                    suite_metrics[name] = dict(last)
            except Exception:
                pass
        payload.update(suite_metrics)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
        # numbered trajectory snapshots only make sense for full runs —
        # a partial --only or shrunk --quick run would mint an index whose
        # metrics are not comparable to the committed full-run snapshots
        if not args.no_snapshot and not args.quick and \
                (args.only is None or args.snapshot is not None):
            snap = write_snapshot(suite_metrics, wall, errors,
                                  index=args.snapshot, skipped=skipped)
            print(f"# wrote {snap}", file=sys.stderr)

    if errors:
        sys.exit(1)        # after persisting partial results


if __name__ == "__main__":
    main()
