"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived`` CSV.  `python -m benchmarks.run [--only X]`.

Suites are imported lazily so `--only` works even when a heavyweight or
optional dependency of an unrelated suite (jax, repro.dist) is missing.

`--json [PATH]` additionally writes the descriptor-plane perf headline
(object-vs-batch speedup, sweep wall clocks) plus per-suite wall-clock
timings to PATH (default ``BENCH_descriptor_plane.json``) so the perf
trajectory is tracked across PRs.
"""

from __future__ import annotations

import argparse
import importlib
import json
import sys
import time

SUITES = [
    ("bus_utilization", "Fig. 8 + §3.1"),
    ("outstanding_sweep", "Fig. 14"),
    ("area_model", "Table 4 / Fig. 12"),
    ("timing_model", "Fig. 13"),
    ("latency", "§4.3"),
    ("workload_speedup", "§3.4 / §3.5 (Fig. 11)"),
    ("descriptor_plane", "SoA vs object descriptor hot path"),
    ("dataplane", "vectorized functional data plane (execute_batch)"),
    ("channel_sweep", "multi-channel aggregate bandwidth (§4 concurrency)"),
    ("kernel_bench", "kernels + TPU rooflines"),
    ("roofline", "dry-run roofline table"),
]

#: suite name → module (descriptor_plane lives in descriptor_plane_bench)
_MODULES = {name: f"benchmarks.{name}" for name, _ in SUITES}
_MODULES["descriptor_plane"] = "benchmarks.descriptor_plane_bench"
_MODULES["dataplane"] = "benchmarks.dataplane_bench"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", nargs="?", const="BENCH_descriptor_plane.json",
                    default=None, metavar="PATH",
                    help="write descriptor-plane perf + suite wall clocks")
    args = ap.parse_args()

    rows = []
    wall = {}
    errors = {}
    for name, what in SUITES:
        if args.only and args.only != name:
            continue
        print(f"# suite: {name} ({what})", file=sys.stderr)
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(_MODULES[name])
            mod.run(rows)
            wall[name] = time.perf_counter() - t0
        except Exception as err:
            # a broken/optional-dependency suite must not discard the
            # rows and timings every suite before it already measured
            if args.only:
                raise
            errors[name] = f"{type(err).__name__}: {err}"
            print(f"# suite {name} FAILED: {errors[name]}", file=sys.stderr)
    print("name,value,derived")
    for name, value, derived in rows:
        if isinstance(value, float):
            value = f"{value:.6g}"
        print(f"{name},{value},{derived}")

    if args.json:
        payload = {"suite_wall_clock_s": wall}
        if errors:
            payload["suite_errors"] = errors
        # persist any suite's module-level LAST dict (partial data survives
        # a failed gate; import-time failures are already in suite_errors)
        for name in sorted(set(wall) | set(errors)):
            try:
                last = getattr(importlib.import_module(_MODULES[name]),
                               "LAST", None)
                if last:
                    payload[name] = dict(last)
            except Exception:
                pass
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)

    if errors:
        sys.exit(1)        # after persisting partial results


if __name__ == "__main__":
    main()
