"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived`` CSV.  `python -m benchmarks.run [--only X]``.
"""

from __future__ import annotations

import argparse
import sys

from . import (area_model, bus_utilization, kernel_bench, latency,
               outstanding_sweep, roofline, timing_model, workload_speedup)

SUITES = [
    ("bus_utilization", bus_utilization),     # Fig. 8 + §3.1
    ("outstanding_sweep", outstanding_sweep),  # Fig. 14
    ("area_model", area_model),               # Table 4 / Fig. 12
    ("timing_model", timing_model),           # Fig. 13
    ("latency", latency),                     # §4.3
    ("workload_speedup", workload_speedup),   # §3.4 / §3.5 (Fig. 11)
    ("kernel_bench", kernel_bench),           # kernels + TPU rooflines
    ("roofline", roofline),                   # dry-run roofline table
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    rows = []
    for name, mod in SUITES:
        if args.only and args.only != name:
            continue
        print(f"# suite: {name}", file=sys.stderr)
        mod.run(rows)
    print("name,value,derived")
    for name, value, derived in rows:
        if isinstance(value, float):
            value = f"{value:.6g}"
        print(f"{name},{value},{derived}")


if __name__ == "__main__":
    main()
