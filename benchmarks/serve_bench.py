"""Closed-loop serving benchmark: continuous batching vs a fixed
padded batch, at the same offered load.

Poisson arrivals (seeded, in **simulated engine cycles** — the clock is
`ChannelSimResult.total_cycles` per drain plus a fixed per-step model
overhead, so every number here is deterministic) are fed to two front
ends over identical request traces:

* **continuous** — `ServeFrontDoor`: paged-KV block allocator, FCFS
  admission + LIFO preemption with DMA-expressed swap, chunked prefill,
  per-request decode gathers, interrupt-driven completion;
* **padded baseline** — the `ServeEngine` batching model expressed as
  the same descriptor traffic: requests are taken in fixed batches of
  ``B = n_pages // pages_per_request`` (static worst-case block
  reservation — no paging flexibility), prompts left-padded to the
  batch max, every slot gathers every step until the whole batch
  drains (head-of-line blocking), late arrivals wait for the next
  batch.

Both run the same `HashLM` byte-contract model, so "correct" is a hard
equality against the sequential one-request-at-a-time oracle
(`oracle_generate`) — any descriptor-plane corruption (bad swap, stale
gather) changes tokens.

Gates: continuous ≥ 2x baseline tokens/cycle, byte-identical outputs to
the oracle on both paths, plan-cache hit rate ≥ 90% under churn,
preemption actually exercised, zero leaked blocks/swap slots at drain.

Reported: tokens per Mcycle, p50/p99 request latency (kcycles),
preemption/swap counts, plan-cache hit rate.  Results land in ``LAST``
for ``benchmarks/run.py --json`` snapshots.
"""

from __future__ import annotations

import time
from collections import deque

import numpy as np

from repro.core import (MemoryMap, PlanCache, Protocol, build_engine,
                        concat_batches)
from repro.serve import KVLayout
from repro.serve.kvcache import (gather_descriptors,
                                 span_append_descriptors)
from repro.serve.sched import (HashLM, ServeFrontDoor, ServeRequest,
                               oracle_generate)
from repro.serve.sched.front import serve_spec

GATE_SPEEDUP = 2.0
GATE_HIT_RATE = 0.90

#: last run's headline numbers, for `benchmarks.run --json`
LAST = {}


def _make_trace(n_reqs: int, interarrival: int, vocab: int,
                max_prompt: int, max_new: int, seed: int = 0):
    """One seeded request trace: Poisson arrivals, ragged lengths,
    mixed temperatures, per-request stop tokens."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(interarrival, size=n_reqs)
    arrivals = np.cumsum(gaps).astype(np.int64)
    reqs = []
    for rid in range(n_reqs):
        plen = int(rng.integers(4, max_prompt + 1))
        reqs.append(ServeRequest(
            rid=rid,
            prompt=list(map(int, rng.integers(0, vocab, plen))),
            max_new_tokens=int(rng.integers(4, max_new + 1)),
            temperature=float(rng.choice([0.0, 0.0, 0.7, 1.2])),
            seed=int(rng.integers(0, 1 << 31)),
        ))
    return reqs, arrivals


def _clone(reqs):
    return [ServeRequest(rid=r.rid, prompt=list(r.prompt),
                         max_new_tokens=r.max_new_tokens,
                         temperature=r.temperature,
                         stop_tokens=r.stop_tokens, seed=r.seed)
            for r in reqs]


class PaddedBaseline:
    """The fixed left-padded batch serving model, expressed as the same
    engine traffic the continuous front door produces — static
    worst-case block reservation, whole-batch gathers every step, batch
    drains before the next one forms."""

    def __init__(self, model: HashLM, layout: KVLayout, max_seq_len: int,
                 num_channels: int = 4,
                 step_overhead_cycles: int = 1000) -> None:
        self.model = model
        self.layout = layout
        self.pages_per_req = -(-max_seq_len // layout.page_size)
        self.batch = layout.n_pages // self.pages_per_req
        if self.batch < 1:
            raise ValueError("pool smaller than one padded reservation")
        self.step_overhead_cycles = step_overhead_cycles
        gather_bytes = self.pages_per_req * layout.page_bytes
        self._stride = 2 * gather_bytes          # gather-K | gather-V
        stage_bytes = max_seq_len * layout.row_bytes
        self._stage0 = self.batch * self._stride
        self._stage_stride = 2 * stage_bytes
        mem = MemoryMap.create({
            Protocol.HBM: 2 * layout.pool_bytes,
            Protocol.VMEM: self._stage0
            + self.batch * self._stage_stride,
            Protocol.HOST: layout.page_bytes,    # unused: no swap
        })
        self.plan_cache = PlanCache(capacity=256)
        self.engine = build_engine(serve_spec(num_channels), mem=mem,
                                   plan_cache=self.plan_cache)
        # static slot-major page reservation
        self.slot_blocks = [
            list(range(s * self.pages_per_req,
                       (s + 1) * self.pages_per_req))
            for s in range(self.batch)]
        self.clock = 0
        self.decode_tokens = 0
        self.steps = 0
        self.batches = 0

    def _drain(self) -> None:
        self.clock += self.engine.wait_all().total_cycles

    def _stage_and_append(self, slot: int, blocks, rows_k, rows_v,
                          start: int, end: int) -> None:
        lay = self.layout
        vmem = self.engine.mem.spaces[Protocol.VMEM]
        sk = self._stage0 + slot * self._stage_stride
        sv = sk + (end - start) * lay.row_bytes
        vmem[sk:sk + rows_k.size] = rows_k.reshape(-1)
        vmem[sv:sv + rows_v.size] = rows_v.reshape(-1)
        self.engine.dispatch_batch(span_append_descriptors(
            lay, blocks, start, end, stage_k=sk, stage_v=sv))

    def run(self, reqs, arrivals) -> list:
        lay = self.layout
        queue = deque(zip(reqs, arrivals))
        finish_latency = []
        while queue:
            if queue[0][1] > self.clock:
                self.clock = int(queue[0][1])   # idle until next arrival
            batch = []
            while queue and queue[0][1] <= self.clock and \
                    len(batch) < self.batch:
                batch.append(queue.popleft()[0])
            self.batches += 1
            P = max(len(r.prompt) for r in batch)
            pads = [P - len(r.prompt) for r in batch]
            # padded prefill: every slot appends P rows (pad rows are
            # zero-content — padded batches compute KV for pads too)
            for s, (req, pad) in enumerate(zip(batch, pads)):
                rows_k = np.zeros((P, lay.row_bytes), np.uint8)
                rows_v = np.zeros((P, lay.row_bytes), np.uint8)
                n = len(req.prompt)
                rows_k[pad:] = self.model.kv_rows(req.seed, req.tokens,
                                                  0, n, "k")
                rows_v[pad:] = self.model.kv_rows(req.seed, req.tokens,
                                                  0, n, "v")
                self._stage_and_append(s, self.slot_blocks[s], rows_k,
                                       rows_v, 0, P)
            self._drain()
            self.clock += self.step_overhead_cycles
            # decode: the whole batch gathers every step until every
            # request is done (head-of-line blocking)
            done = [False] * len(batch)
            t = 0
            while not all(done):
                L = P + t
                npages = -(-L // lay.page_size)
                vmem = self.engine.mem.spaces[Protocol.VMEM]
                for s in range(len(batch)):
                    table = np.asarray(self.slot_blocks[s][:npages],
                                       dtype=np.int64)[None, :]
                    gk = s * self._stride
                    gv = gk + self.pages_per_req * lay.page_bytes
                    self.engine.dispatch_batch(concat_batches([
                        gather_descriptors(lay, table,
                                           npages * lay.page_size,
                                           pool_base=0, dst_base=gk),
                        gather_descriptors(lay, table,
                                           npages * lay.page_size,
                                           pool_base=lay.pool_bytes,
                                           dst_base=gv)]))
                self._drain()
                live = [i for i, d in enumerate(done) if not d]
                views, gathered = [], []
                for i in live:
                    req, pad = batch[i], pads[i]
                    n = len(req.tokens)
                    gk = i * self._stride
                    gv = gk + self.pages_per_req * lay.page_bytes
                    a = pad * lay.row_bytes
                    b = (pad + n) * lay.row_bytes
                    views.append(req)
                    gathered.append((vmem[gk + a:gk + b],
                                     vmem[gv + a:gv + b]))
                toks = self.model.next_tokens(views, gathered)
                self.steps += 1
                for i, tok in zip(live, toks):
                    req, pad = batch[i], pads[i]
                    req.output.append(tok)
                    req.tokens.append(tok)
                    self.decode_tokens += 1
                    if (len(req.output) >= req.max_new_tokens
                            or tok in req.stop_tokens
                            or tok == self.model.eos_token):
                        done[i] = True
                        req.finish_cycle = self.clock \
                            + self.step_overhead_cycles
                        finish_latency.append(req.finish_cycle
                                              - req.arrival_cycle)
                    else:
                        t0 = len(req.tokens) - 1
                        rk = self.model.kv_rows(req.seed, req.tokens,
                                                t0, t0 + 1, "k")
                        rv = self.model.kv_rows(req.seed, req.tokens,
                                                t0, t0 + 1, "v")
                        self._stage_and_append(i, self.slot_blocks[i],
                                               rk, rv, pad + t0,
                                               pad + t0 + 1)
                if any(not d for d in done):
                    self._drain()
                self.clock += self.step_overhead_cycles
                t += 1
        return finish_latency


def run(csv_rows, quick: bool = False):
    t_wall = time.perf_counter()
    layout = KVLayout(n_pages=160 if quick else 192, page_size=8,
                      n_kv_heads=2, head_dim=16, itemsize=4)
    max_prompt, max_new = 64, 40
    max_seq_len = max_prompt + max_new + 8                    # 112 → 14 pp
    n_reqs = 200 if quick else 2000
    interarrival = 2500
    vocab = 64
    model = HashLM(layout.row_bytes, vocab=vocab, eos_token=1)
    reqs, arrivals = _make_trace(n_reqs, interarrival, vocab,
                                 max_prompt, max_new, seed=11)

    # -- continuous batching -------------------------------------------------
    cont = _clone(reqs)
    fd = ServeFrontDoor(model, layout, max_seq_len=max_seq_len,
                        max_running=32, prefill_chunk=16,
                        low_watermark=8, num_channels=4,
                        completion="irq", plan_cache=256)
    for r, at in zip(cont, arrivals):
        fd.submit(r, at_cycle=int(at))
    metrics = fd.run()
    for r, at in zip(cont, arrivals):
        r.arrival_cycle = int(at)
    cont_lat = np.asarray([r.finish_cycle - r.arrival_cycle
                           for r in cont], dtype=np.float64)
    cont_tpm = metrics.decode_tokens / (metrics.cycles / 1e6)
    hit_rate = fd.plan_cache.stats.hit_rate

    # -- padded fixed-batch baseline (same trace, same pool size) ------------
    base = _clone(reqs)
    for r, at in zip(base, arrivals):
        r.tokens = list(r.prompt)
        r.arrival_cycle = int(at)
    baseline = PaddedBaseline(model, layout, max_seq_len,
                              num_channels=4)
    base_lat = np.asarray(baseline.run(base, arrivals), dtype=np.float64)
    base_tpm = baseline.decode_tokens / (baseline.clock / 1e6)

    # -- gates ---------------------------------------------------------------
    oracle_bad = []
    for a, b in zip(cont, base):
        want = oracle_generate(model, a.seed, a.prompt, a.max_new_tokens,
                               a.temperature, a.stop_tokens)
        if a.output != want:
            oracle_bad.append(("continuous", a.rid))
        if b.output != want:
            oracle_bad.append(("baseline", b.rid))
    speedup = cont_tpm / base_tpm
    stats = fd.alloc.stats
    leaked = len(fd.alloc.leaked())

    p50c, p99c = np.percentile(cont_lat, [50, 99]) / 1e3
    p50b, p99b = np.percentile(base_lat, [50, 99]) / 1e3
    csv_rows.append(("serve_requests", n_reqs, ""))
    csv_rows.append(("serve_cont_tokens_per_mcycle", cont_tpm, ""))
    csv_rows.append(("serve_base_tokens_per_mcycle", base_tpm, ""))
    csv_rows.append(("serve_speedup", speedup,
                     f"target>={GATE_SPEEDUP:g}x"))
    csv_rows.append(("serve_cont_p50_kcycles", p50c, ""))
    csv_rows.append(("serve_cont_p99_kcycles", p99c, ""))
    csv_rows.append(("serve_base_p50_kcycles", p50b, ""))
    csv_rows.append(("serve_base_p99_kcycles", p99b, ""))
    csv_rows.append(("serve_preemptions", stats.preemptions, ""))
    csv_rows.append(("serve_plan_cache_hit_rate", hit_rate,
                     f"target>={GATE_HIT_RATE:g}"))

    LAST.update({
        "requests": n_reqs,
        "interarrival_cycles": interarrival,
        "quick": quick,
        "continuous": {
            "tokens": metrics.decode_tokens,
            "cycles": metrics.cycles,
            "steps": metrics.steps,
            "tokens_per_mcycle": cont_tpm,
            "p50_latency_kcycles": p50c,
            "p99_latency_kcycles": p99c,
            "preemptions": stats.preemptions,
            "swapped_out_blocks": stats.swapped_out,
            "swapped_in_blocks": stats.swapped_in,
            "growth_stall_steps": fd.sched.stats.stall_steps,
            "plan_cache_hit_rate": hit_rate,
        },
        "baseline": {
            "tokens": baseline.decode_tokens,
            "cycles": baseline.clock,
            "steps": baseline.steps,
            "batches": baseline.batches,
            "batch_slots": baseline.batch,
            "tokens_per_mcycle": base_tpm,
            "p50_latency_kcycles": p50b,
            "p99_latency_kcycles": p99b,
        },
        "speedup": speedup,
        "oracle_identical": not oracle_bad,
        "leaked_blocks": leaked,
        "wall_clock_s": time.perf_counter() - t_wall,
    })

    assert not oracle_bad, \
        f"outputs diverged from the sequential oracle: {oracle_bad[:5]}"
    assert leaked == 0, f"{leaked} KV blocks leaked at drain"
    assert hit_rate >= GATE_HIT_RATE, \
        f"plan-cache hit rate {hit_rate:.3f} under churn " \
        f"(need >= {GATE_HIT_RATE})"
    assert stats.preemptions > 0, \
        "benchmark never preempted — churn not exercised"
    assert speedup >= GATE_SPEEDUP, \
        f"continuous batching only {speedup:.2f}x over the padded " \
        f"baseline (need >= {GATE_SPEEDUP:g}x)"


if __name__ == "__main__":
    rows = []
    run(rows)
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")
