"""Plan-replay benchmark: compile-once / replay-many paged-KV decode.

The steady-state serving loop re-submits structurally identical
append/gather descriptor batches every decode step with only page-table
base addresses changed.  This suite drives `PagedKVDMA` (functional
serving configuration, ``timing=False``) through a >= 1024-step decode
loop over realistic (shuffled-allocation) page tables twice:

* **uncached** — every submission runs `legalize_batch` + grouped
  `execute_batch`, exactly the PR-3 data plane;
* **cached**   — the per-`KVLayout` plan templates (`core.plan`): capture
  on the first step, then every submission is a vectorized
  ``base[desc] + offset`` rebind replayed with frozen grouping hints.

Both loops append one token per step (K and V in ONE descriptor batch —
one doorbell) and gather a sliding attention window of whole pages, the
decode access pattern of a windowed-attention server.  The benchmark
asserts byte-identity between the two loops — every per-step gather
result and the final physical pools — and gates the cached loop at
**>= 5x** over the uncached one.  Cycle-identity of replayed plans is
covered by `tests/test_plan.py`.

Results land in ``LAST`` for ``benchmarks/run.py --json`` / the
``BENCH_<n>.json`` perf-trajectory snapshots.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.analytics import plan_cache_profile
from repro.serve.kvcache import KVLayout, PagedKVDMA, PagePool, \
    make_page_tables

STEPS = 1024
B = 8                        # decode batch (sequences)
WINDOW_PAGES = 8             # gathered attention window, in pages
PAGE_SIZE = 2                # tokens per page
HKV, DH, ITEMSIZE = 1, 8, 2  # row_bytes = 16 B, page_bytes = 32 B
GATE = 5.0

#: last run's headline numbers, for `benchmarks.run --json`
LAST = {}


def _setup(seed: int = 0):
    """Layout, shuffled page tables and pregenerated token stream."""
    rng = np.random.default_rng(seed)
    prefill = WINDOW_PAGES * PAGE_SIZE
    total_tokens = prefill + STEPS
    pages_per_seq = -(-total_tokens // PAGE_SIZE)
    n_pages = B * pages_per_seq
    layout = KVLayout(n_pages, PAGE_SIZE, HKV, DH, itemsize=ITEMSIZE)
    alloc = PagePool(n_pages, PAGE_SIZE)
    rng.shuffle(alloc.free)              # realistic, non-linear allocation
    tables = make_page_tables(alloc, B, total_tokens)
    kv = rng.standard_normal((total_tokens, 2, B, HKV, DH)) \
        .astype(np.float16)
    return layout, tables, kv, prefill


def _decode_loop(layout, tables, kv, prefill, plan_cache):
    """One full decode run; returns (elapsed_s, per-step gather digests,
    final pools, dma)."""
    window = WINDOW_PAGES * PAGE_SIZE
    dma = PagedKVDMA(layout, max_batch=B, max_len=window, timing=False,
                     plan_cache=plan_cache)
    # prefill the first window outside the timed region
    for pos in range(prefill):
        dma.append(tables, pos, kv[pos, 0], kv[pos, 1])

    outs = []
    t0 = time.perf_counter()
    for step in range(STEPS):
        pos = prefill + step
        dma.append(tables, pos, kv[pos, 0], kv[pos, 1])
        p0 = (pos + 1) // PAGE_SIZE - WINDOW_PAGES     # sliding window
        k, v = dma.gather(tables[:, p0:p0 + WINDOW_PAGES], window)
        outs.append((k, v))
    elapsed = time.perf_counter() - t0
    pools = (dma._pool("k").copy(), dma._pool("v").copy())
    return elapsed, outs, pools, dma


REPEATS = 3                  # best-of-N wall clocks (identical runs)


def run(csv_rows):
    layout, tables, kv, prefill = _setup()

    t_uncached = t_cached = float("inf")
    for _ in range(REPEATS):
        t, outs_u, pools_u, _ = _decode_loop(
            layout, tables, kv, prefill, plan_cache=False)
        t_uncached = min(t_uncached, t)
        t, outs_c, pools_c, dma = _decode_loop(
            layout, tables, kv, prefill, plan_cache=True)
        t_cached = min(t_cached, t)

    # byte-identity: every per-step gather and the final physical pools
    for step, ((ku, vu), (kc, vc)) in enumerate(zip(outs_u, outs_c)):
        assert np.array_equal(ku, kc) and np.array_equal(vu, vc), \
            f"plan replay diverged from the uncached path at step {step}"
    assert np.array_equal(pools_u[0], pools_c[0])
    assert np.array_equal(pools_u[1], pools_c[1])

    speedup = t_uncached / t_cached
    profile = plan_cache_profile(dma.plan_cache)
    steps_per_s = STEPS / t_cached
    csv_rows.append(("plan_replay_decode_steps", STEPS, ""))
    csv_rows.append(("plan_replay_uncached_s", t_uncached, ""))
    csv_rows.append(("plan_replay_cached_s", t_cached, ""))
    csv_rows.append(("plan_replay_speedup", speedup,
                     f"target>={GATE:g}x"))
    csv_rows.append(("plan_replay_cached_steps_per_s", steps_per_s, ""))
    csv_rows.append(("plan_replay_hit_rate", profile["hit_rate"], ""))

    LAST.update({
        "decode_steps": STEPS,
        "batch": B,
        "window_pages": WINDOW_PAGES,
        "uncached_s": t_uncached,
        "cached_s": t_cached,
        "speedup": speedup,
        "cached_steps_per_s": steps_per_s,
        "plan_cache": profile,
    })
    assert speedup >= GATE, \
        f"plan replay only {speedup:.2f}x over uncached (need >= {GATE:g}x)"


if __name__ == "__main__":
    rows = []
    run(rows)
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")
