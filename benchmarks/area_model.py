"""Table 4 / Fig. 12 reproduction: area model decomposition + parameter
scaling, validated against the paper's published anchor points."""

from __future__ import annotations

from repro.core import analytics as A
from repro.core.analytics import PortConfig
from repro.core.descriptor import Protocol


def run(csv_rows):
    # Table 4 decomposition at the PULP configuration
    bd = A.area_model(A.pulp_cluster_ports(), aw=32, dw=32, nax=16)
    for part, ge in bd.as_dict().items():
        csv_rows.append((f"table4_pulp_{part}_GE", ge, ""))

    # Fig. 12 scaling sweeps from the base configuration
    for dw in (32, 64, 128, 256, 512):
        csv_rows.append((f"fig12a_area_dw{dw}_GE",
                         A.area_model(A.base_axi_ports(), dw=dw).total, ""))
    for aw in (32, 48, 64):
        csv_rows.append((f"fig12b_area_aw{aw}_GE",
                         A.area_model(A.base_axi_ports(), aw=aw).total, ""))
    for nax in (2, 4, 8, 16, 32, 64):
        csv_rows.append((f"fig12c_area_nax{nax}_GE",
                         A.area_model(A.base_axi_ports(), nax=nax).total,
                         ""))

    # paper anchors
    csv_rows.append(("area_32b_32ot_GE",
                     A.area_model(A.base_axi_ports(), nax=32).total,
                     "paper=<25000"))
    csv_rows.append(("area_GE_per_outstanding",
                     A.ge_per_outstanding(A.base_axi_ports()),
                     "paper=~400"))
    csv_rows.append(("area_obi_minimal_GE",
                     A.area_model([PortConfig(Protocol.OBI)], nax=1,
                                  has_legalizer=False).total,
                     "paper=>=2000 (IO-DMA class)"))
