"""Data-plane benchmark: vectorized `execute_batch` vs the scalar oracle.

PR 1 vectorized the *timing* plane (legalize/simulate); this suite gates
the *functional* plane — the path that actually moves bytes (paper §2.3).
Three measurements:

1. A 1M-burst random scatter/gather stream (disjoint 64-B slots, ragged
   1..64-B bursts, HBM→VMEM) executed byte-for-byte on the scalar path
   (`execute`: per-burst Python loop over `Transfer1D` objects) and on the
   batch path (`execute_batch`: grouped gather/scatter with fancy
   indexing).  Asserts the destinations are byte-identical and the batch
   path is >= 10x faster — the CI gate.

2. The same stream with the destination permutation removed (a linear
   copy), batch path only — the dense upper bound for the grouped
   gather/scatter.

3. A 1M-burst Init (pseudorandom) fill through the vectorized splitmix32
   stream generator — the generator-protocol data plane at scale.

Results are stashed in the module-level ``LAST`` dict so
``benchmarks/run.py --json`` persists them as the perf trajectory.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (BackendOptions, DescriptorBatch, InitPattern,
                        MemoryMap, Protocol, execute, execute_batch,
                        legalize_batch)

N = 1_000_000
SLOT = 64                     # address slot per burst; lengths are 1..SLOT
BUS = 8

#: last run's headline numbers, for `benchmarks.run --json`
LAST = {}


def scatter_gather_stream(n: int = N, slot: int = SLOT, seed: int = 0,
                          scatter: bool = True) -> DescriptorBatch:
    """`n` ragged bursts between disjoint `slot`-aligned windows: every
    burst owns its own source and destination slot (permuted when
    `scatter`), so the stream is order-independent — the byte-identity
    check between the scalar and grouped paths is exact."""
    rng = np.random.default_rng(seed)
    length = rng.integers(1, slot + 1, n).astype(np.int64)
    src = rng.permutation(n).astype(np.int64) * slot
    dst = (rng.permutation(n) if scatter
           else np.arange(n)).astype(np.int64) * slot
    return DescriptorBatch.from_arrays(
        src_addr=src, dst_addr=dst, length=length,
        src_protocol=Protocol.HBM, dst_protocol=Protocol.VMEM)


def _mem(n: int = N, slot: int = SLOT, seed: int = 1) -> MemoryMap:
    mem = MemoryMap.create({Protocol.HBM: n * slot, Protocol.VMEM: n * slot})
    rng = np.random.default_rng(seed)
    mem.spaces[Protocol.HBM][:] = rng.integers(
        0, 256, n * slot, dtype=np.uint8)
    return mem


def run(csv_rows, quick=False):
    # --quick shrinks the streams 20x and relaxes the speedup gate: the
    # byte-identity checks still run in full, only the timing headline
    # loses precision (quick runs never write trajectory snapshots)
    n = N // 20 if quick else N
    gate = 3.0 if quick else 10.0
    tag = "50k" if quick else "1M"
    legal = legalize_batch(scatter_gather_stream(n=n), bus_width=BUS)
    total = int(legal.length.sum())

    # 1 — scalar oracle vs batch path, byte-identical destinations
    mem_obj = _mem(n=n)
    bursts = legal.to_transfers()          # object materialization untimed
    t0 = time.perf_counter()
    moved_obj = execute(bursts, mem_obj, bus_width=BUS)
    t_obj = time.perf_counter() - t0
    del bursts

    mem_bat = _mem(n=n)
    t_bat = float("inf")
    for _ in range(3):
        mem_bat.spaces[Protocol.VMEM][:] = 0
        t0 = time.perf_counter()
        moved_bat = execute_batch(legal, mem_bat, bus_width=BUS)
        t_bat = min(t_bat, time.perf_counter() - t0)

    assert moved_obj == moved_bat == total
    assert np.array_equal(mem_obj.spaces[Protocol.VMEM],
                          mem_bat.spaces[Protocol.VMEM]), \
        "execute_batch diverged from the scalar oracle"
    del mem_obj
    speedup = t_obj / t_bat
    gbps = total / t_bat / 1e9
    csv_rows.append((f"dataplane_scatter_gather_{tag}_scalar_s", t_obj, ""))
    csv_rows.append((f"dataplane_scatter_gather_{tag}_batch_s", t_bat, ""))
    csv_rows.append((f"dataplane_scatter_gather_{tag}_speedup", speedup,
                     f"target>={gate:.0f}x"))
    csv_rows.append((f"dataplane_scatter_gather_{tag}_GBps", gbps, ""))

    # 2 — dense upper bound: same bursts, linear destination walk
    dense = legalize_batch(scatter_gather_stream(n=n, scatter=False),
                           bus_width=BUS)
    t0 = time.perf_counter()
    execute_batch(dense, mem_bat, bus_width=BUS)
    t_dense = time.perf_counter() - t0
    csv_rows.append((f"dataplane_linear_{tag}_batch_s", t_dense, ""))

    # 3 — generator data plane: pseudorandom Init bursts at scale
    init = DescriptorBatch.from_arrays(
        src_addr=np.arange(n, dtype=np.int64) * SLOT,
        dst_addr=np.arange(n, dtype=np.int64) * SLOT,
        length=np.full(n, SLOT, dtype=np.int64),
        src_protocol=Protocol.INIT, dst_protocol=Protocol.VMEM,
        options=BackendOptions(init_pattern=InitPattern.PSEUDORANDOM,
                               init_value=7))
    t0 = time.perf_counter()
    moved_init = execute_batch(legalize_batch(init, bus_width=BUS), mem_bat,
                               bus_width=BUS)
    t_init = time.perf_counter() - t0
    csv_rows.append((f"dataplane_init_prng_{tag}_s", t_init, ""))
    csv_rows.append((f"dataplane_init_prng_{tag}_GBps",
                     moved_init / t_init / 1e9, ""))

    LAST.update({
        f"scatter_gather_{tag}_scalar_s": t_obj,
        f"scatter_gather_{tag}_batch_s": t_bat,
        f"scatter_gather_{tag}_speedup": speedup,
        f"scatter_gather_{tag}_GBps": gbps,
        f"linear_{tag}_batch_s": t_dense,
        f"init_prng_{tag}_s": t_init,
        "bytes_moved": total,
    })
    assert speedup >= gate, \
        f"execute_batch only {speedup:.1f}x over scalar (need >= {gate}x)"
