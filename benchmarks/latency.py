"""§4.3 reproduction: launch latency across configurations (batch path)."""

from __future__ import annotations

from repro.core import (DescriptorBatch, EngineConfig, SRAM, Transfer1D,
                        simulate_batch)


def run(csv_rows):
    cases = [
        ("base", EngineConfig(bus_width=8), 2),
        ("no_legalizer", EngineConfig(bus_width=8, has_legalizer=False), 1),
        ("one_midend", EngineConfig(bus_width=8, num_midends=1), 3),
        ("two_midends", EngineConfig(bus_width=8, num_midends=2), 4),
        ("tensor_nd_zero",
         EngineConfig(bus_width=8, num_midends=1,
                      tensor_nd_zero_latency=True), 2),
    ]
    one = DescriptorBatch.from_transfers([Transfer1D(0, 0, 64)])
    for name, cfg, expected in cases:
        r = simulate_batch(one, cfg, SRAM, SRAM)
        csv_rows.append((f"latency_{name}_cycles", r.first_read_req,
                         f"paper={expected}"))
    # protocol independence (paper: latency independent of protocol)
    from repro.core import Protocol
    for proto in (Protocol.AXI4, Protocol.OBI, Protocol.TILELINK):
        r = simulate_batch(
            DescriptorBatch.from_transfers(
                [Transfer1D(0, 0, 64, proto, proto)]),
            EngineConfig(bus_width=8), SRAM, SRAM)
        csv_rows.append((f"latency_{proto.value}_cycles", r.first_read_req,
                         "paper=2 (protocol-independent)"))
