"""Sanitizer throughput: the sweep-line must be cheap relative to the
execution it protects.

The workload is the data-plane benchmark's 1M-burst scatter/gather
stream (disjoint 64-B slots, ragged 1..64-B bursts, HBM→VMEM) — the
same program `dataplane_bench` gates `execute_batch` on.  Two numbers:

1. sanitizer wall clock over the 1M-row submission
   (`repro.sanitize.check_batch` — interval build, per-space argsort,
   cummax overlap screen, pair classification);
2. `execute_batch` wall clock over the same program's legalized stream.

The CI gate is their ratio: an *opt-in* analysis that costs more than a
fraction of the run it certifies would never be left enabled, so the
sweep must stay under 10% of the execution time it protects.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import MemoryMap, Protocol, execute_batch, legalize_batch
from repro.sanitize import check_batch

from .dataplane_bench import BUS, N, SLOT, scatter_gather_stream

#: last run's headline numbers, for `benchmarks.run --json`
LAST = {}

#: the CI gate: sanitize wall clock / execute_batch wall clock
RATIO_GATE = 0.10


def run(csv_rows, quick=False):
    n = N // 20 if quick else N
    tag = "50k" if quick else "1M"
    # --quick relaxes the ratio only: small streams under-amortize the
    # sweep's fixed setup against execute_batch's byte movement
    gate = 1.0 if quick else RATIO_GATE

    stream = scatter_gather_stream(n=n)

    t_san = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        report = check_batch(stream)
        t_san = min(t_san, time.perf_counter() - t0)
    assert report.clean, \
        f"scatter/gather stream flagged: {report.codes}"
    assert report.checked_rows == n

    legal = legalize_batch(stream, bus_width=BUS)
    mem = MemoryMap.create({Protocol.HBM: n * SLOT,
                            Protocol.VMEM: n * SLOT})
    rng = np.random.default_rng(1)
    mem.spaces[Protocol.HBM][:] = rng.integers(0, 256, n * SLOT,
                                               dtype=np.uint8)
    t_exec = float("inf")
    for _ in range(3):
        mem.spaces[Protocol.VMEM][:] = 0
        t0 = time.perf_counter()
        execute_batch(legal, mem, bus_width=BUS)
        t_exec = min(t_exec, time.perf_counter() - t0)

    ratio = t_san / t_exec
    rows_per_s = n / t_san
    csv_rows.append((f"sanitize_sweep_{tag}_s", t_san, ""))
    csv_rows.append((f"sanitize_sweep_{tag}_rows_per_s", rows_per_s, ""))
    csv_rows.append((f"sanitize_vs_execute_{tag}_ratio", ratio,
                     f"target<={gate:.2f}"))

    LAST.update({
        f"sweep_{tag}_s": t_san,
        f"sweep_{tag}_rows_per_s": rows_per_s,
        f"execute_{tag}_s": t_exec,
        f"vs_execute_{tag}_ratio": ratio,
    })
    assert ratio <= gate, \
        f"sanitizer costs {ratio:.2f}x of execute_batch (gate {gate:.2f})"
