"""Multi-channel concurrency sweep: aggregate bandwidth vs channel count.

The paper's headline results (§4, Fig. 14) come from concurrent iDMA
instantiations sharing endpoints.  This sweep reproduces the effect with
`simulate_channels`: a fixed 64 KiB workload of 16 B descriptors is split
evenly over 1..8 channels, every channel issuing against the *same*
`MemSystem` pair, and we track aggregate bandwidth (useful bytes per
makespan cycle):

* **SRAM** (3-cycle latency): a single channel already keeps the data
  port busy, so extra channels buy little — the shared port is the cap.
* **HBM** (100-cycle latency, 64 outstanding): a single channel with
  NAx=2 leaves the endpoint idle between bursts; concurrent channels
  overlap their latency windows and aggregate bandwidth scales until the
  shared data port / credit window saturates.
* **HBM-tight** (100-cycle latency, `outstanding=2` *shared* across
  channels): the endpoint's request-credit budget caps scaling — adding
  channels cannot create credits.

Gates (CI): >= 1.5x aggregate throughput for 4 channels vs 1 on HBM;
<= 1.2x on the shared-credit-starved endpoint.

Standalone: ``PYTHONPATH=src python -m benchmarks.channel_sweep [--json
PATH]`` prints the CSV and optionally writes the sweep as JSON.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.core import (HBM, SRAM, EngineConfig, MemSystem,
                        make_fragmented_batch, simulate_channels)

TOTAL = 64 * 1024
FRAGMENT = 16
CHANNELS = (1, 2, 3, 4, 6, 8)

#: HBM with a starved shared request-credit window (outstanding is the
#: *shared* budget across channels in `simulate_channels`).
HBM_TIGHT = MemSystem("HBM-tight", latency=100, outstanding=2)

SYSTEMS = (SRAM, HBM, HBM_TIGHT)

#: last run's headline numbers, for `benchmarks.run --json`
LAST = {}


def sweep_system(mem: MemSystem, cfg: EngineConfig,
                 channels=CHANNELS, total: int = TOTAL,
                 fragment: int = FRAGMENT):
    """Aggregate bandwidth (bytes/cycle) per channel count, equal work
    split; total bytes moved are channel-count-invariant."""
    out = {}
    for n in channels:
        per = total // n
        batches = [make_fragmented_batch(per, fragment) for _ in range(n)]
        res = simulate_channels(batches, cfg, (mem, mem))
        assert res.aggregate.useful_bytes == (total // n) * n
        out[n] = res.aggregate_bandwidth
    return out


def run(csv_rows, engine=None):
    cfg = EngineConfig(bus_width=4, n_outstanding=2)
    sweeps = {}
    for mem in SYSTEMS:
        bw = sweep_system(mem, cfg)
        sweeps[mem.name] = bw
        for n, v in bw.items():
            csv_rows.append((f"chan_{mem.name}_{n}ch_bw", v, "bytes/cycle"))
        csv_rows.append((f"chan_{mem.name}_4ch_speedup", bw[4] / bw[1], ""))

    if engine is not None:
        # --engine <preset>: re-run the sweep on the preset's bundled
        # timing models — its EngineConfig against its own (src, dst)
        # endpoint pair (channels share both, as in the main sweep)
        from repro.core.spec import preset
        spec = preset(engine)
        pcfg = spec.effective_sim_config
        # dedupe: src == dst presets (e.g. cheshire) sweep once
        for mem in dict.fromkeys((spec.src_system, spec.dst_system)):
            bw = sweep_system(mem, pcfg)
            label = f"{spec.name}_{mem.name}"
            sweeps[label] = bw
            for n, v in bw.items():
                csv_rows.append((f"chan_{label}_{n}ch_bw", v,
                                 "bytes/cycle"))
            csv_rows.append((f"chan_{label}_4ch_speedup",
                             bw[4] / bw[1], ""))
        LAST["engine_preset"] = spec.name

    hbm_x4 = sweeps["HBM"][4] / sweeps["HBM"][1]
    tight_x4 = sweeps["HBM-tight"][4] / sweeps["HBM-tight"][1]
    LAST.update({
        "sweeps": sweeps,
        "hbm_4ch_vs_1ch": hbm_x4,
        "tight_4ch_vs_1ch": tight_x4,
    })
    assert hbm_x4 >= 1.5, \
        f"4-channel HBM speedup only {hbm_x4:.2f}x (need >= 1.5x)"
    assert tight_x4 <= 1.2, \
        f"shared-credit endpoint scaled {tight_x4:.2f}x (should be capped)"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_channel_sweep.json",
                    default=None, metavar="PATH")
    args = ap.parse_args()
    rows = []
    run(rows)
    print("name,value,derived")
    for name, value, derived in rows:
        if isinstance(value, float):
            value = f"{value:.6g}"
        print(f"{name},{value},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(LAST, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
