"""Descriptor-plane benchmark: structure-of-arrays vs per-object hot path.

Three measurements:

1. The paper's worst-case Fig. 14 sweep cell — 64 KiB copied as 65 536
   one-byte descriptors — timed on the object path (`fragmented_copy_
   reference`: one frozen `Transfer1D` per descriptor, scalar legalizer,
   per-burst dict bookkeeping) and on the batch path (`DescriptorBatch` +
   `legalize_batch` + `simulate_batch`).  Asserts the batch path is >= 10x
   faster and cycle-identical.

2. The full Fig. 14 sweep (11 fragment sizes x 3 memory systems) wall
   clock on the batch path — the number tracked across PRs via
   ``benchmarks.run --json``.

3. A 1M-descriptor random scatter/gather stream — infeasible on the
   object path (it would materialize and walk millions of dataclass
   instances) — which must legalize + simulate in under 10 s.

Results are also stashed in the module-level ``LAST`` dict so
``benchmarks/run.py --json`` can persist them as
``BENCH_descriptor_plane.json``.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (HBM, RPC_DRAM, SRAM, DescriptorBatch, EngineConfig,
                        fragmented_copy, fragmented_copy_reference,
                        legalize_batch, simulate_batch)
from repro.core.analytics import burst_profile

TOTAL = 64 * 1024
SWEEP_FRAGS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)
SWEEP_SYSTEMS = (SRAM, RPC_DRAM, HBM)
SCATTER_N = 1_000_000

#: last run's headline numbers, for `benchmarks.run --json`
LAST = {}


def _best_of(fn, repeats=3):
    best = float("inf")
    result = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def scatter_gather_batch(n: int = SCATTER_N, seed: int = 0
                         ) -> DescriptorBatch:
    """Random scatter/gather stream: `n` descriptors of 1..511 B at
    arbitrary (misaligned) addresses in a 1 GiB window."""
    rng = np.random.default_rng(seed)
    return DescriptorBatch.from_arrays(
        src_addr=rng.integers(0, 1 << 30, n),
        dst_addr=rng.integers(0, 1 << 30, n),
        length=rng.integers(1, 512, n))


def run(csv_rows, quick=False):
    cfg = EngineConfig(bus_width=4, n_outstanding=16)
    # --quick shrinks the copied total 8x, trims the sweep to one memory
    # system and three fragment sizes, and relaxes the speedup gate; the
    # cycle-identity assertions still run in full (quick runs never write
    # trajectory snapshots)
    total = TOTAL // 8 if quick else TOTAL
    gate = 3.0 if quick else 10.0
    sweep_systems = (SRAM,) if quick else SWEEP_SYSTEMS
    sweep_frags = (1, 16, 256) if quick else SWEEP_FRAGS
    scatter_n = SCATTER_N // 20 if quick else SCATTER_N

    # 1 — object vs batch on the worst-case 1 B fragment cell
    # (like-for-like best-of-N on both sides so the tracked speedup is
    # not warm-up bias; one higher-repeat retry guards the gate against
    # transient load)
    t_obj = t_bat = speedup = 0.0
    for repeats in (2, 5):
        o, r_obj = _best_of(
            lambda: fragmented_copy_reference(total, 1, cfg, SRAM, SRAM),
            repeats=repeats)
        b, r_bat = _best_of(
            lambda: fragmented_copy(total, 1, cfg, SRAM, SRAM),
            repeats=repeats)
        assert r_obj.cycles == r_bat.cycles, \
            f"batch path diverged: {r_obj.cycles} != {r_bat.cycles}"
        t_obj, t_bat = o, b
        speedup = t_obj / t_bat
        if speedup >= gate:
            break
    kib = total // 1024
    csv_rows.append((f"descplane_{kib}KiB_1B_object_s", t_obj, ""))
    csv_rows.append((f"descplane_{kib}KiB_1B_batch_s", t_bat, ""))
    csv_rows.append((f"descplane_{kib}KiB_1B_speedup", speedup,
                     f"target>={gate:.0f}x"))
    LAST.update({f"speedup_{kib}KiB_1B": speedup,
                 f"object_path_{kib}KiB_1B_s": t_obj,
                 f"batch_path_{kib}KiB_1B_s": t_bat})
    assert speedup >= gate, \
        f"SoA descriptor plane only {speedup:.1f}x faster (need >= {gate}x)"

    # 2 — Fig. 14 sweep wall clock on the batch path
    def sweep():
        for mem in sweep_systems:
            for frag in sweep_frags:
                fragmented_copy(total, frag, cfg, mem, mem)
    t0 = time.perf_counter()
    sweep()
    t_sweep = time.perf_counter() - t0
    cells = len(sweep_systems) * len(sweep_frags)
    csv_rows.append(("descplane_fig14_sweep_wall_s", t_sweep,
                     f"{cells} cells"))

    # 3 — bulk scatter/gather, batch path only
    sg_tag = "1M" if scatter_n == 1_000_000 else "50k"
    batch = scatter_gather_batch(n=scatter_n)
    t0 = time.perf_counter()
    res = simulate_batch(batch, cfg, SRAM, SRAM)   # legalizes internally
    t_sg = time.perf_counter() - t0
    prof = burst_profile(legalize_batch(batch, bus_width=cfg.bus_width),
                         bus_width=cfg.bus_width)
    csv_rows.append((f"descplane_scatter_gather_{sg_tag}_s", t_sg,
                     "limit<10s"))
    csv_rows.append((f"descplane_scatter_gather_{sg_tag}_bursts",
                     prof["n_bursts"], ""))
    csv_rows.append((f"descplane_scatter_gather_{sg_tag}_shifter_eff",
                     prof["shifter_efficiency"], ""))
    LAST.update({
        "fig14_sweep_wall_s": t_sweep,
        f"scatter_gather_{sg_tag}_s": t_sg,
        f"scatter_gather_{sg_tag}_bursts": int(prof["n_bursts"]),
    })
    assert t_sg < 10.0, \
        f"1M scatter/gather took {t_sg:.1f}s (limit 10s)"
    assert res.useful_bytes == int(batch.length.sum())
