"""Kernel micro-benchmarks: wall time per call in interpret/XLA mode on
CPU (sanity/regression numbers) + per-kernel VMEM/roofline derivation from
the BlockSpec geometry (the TPU-side analytical numbers)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import plan_nd_copy
from repro.launch.analysis import HBM_BW, PEAK_FLOPS


def _time(fn, *args, reps=3):
    fn(*args)                               # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6   # us


def run(csv_rows):
    rng = np.random.default_rng(0)

    # copy engine (XLA path wall time + TPU analytical)
    from repro.kernels.copy_engine import copy_2d
    x = jnp.asarray(rng.standard_normal((2048, 2048)), jnp.float32)
    us = _time(lambda a: copy_2d(a, backend="xla"), x)
    csv_rows.append(("copy2d_2048_xla_us", us, ""))
    plan = plan_nd_copy((2048, 2048), 4)
    tpu_us = 2 * 2048 * 2048 * 4 / HBM_BW * 1e6
    csv_rows.append(("copy2d_2048_tpu_roofline_us", tpu_us,
                     f"tile={plan.tile},buffers={plan.n_buffers},"
                     f"vmem={plan.vmem_bytes}"))

    # matmul
    from repro.kernels.matmul_dma import matmul
    a = jnp.asarray(rng.standard_normal((1024, 1024)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((1024, 1024)), jnp.float32)
    us = _time(lambda p, q: matmul(p, q, backend="xla"), a, b)
    csv_rows.append(("matmul_1024_xla_us", us, ""))
    csv_rows.append(("matmul_1024_tpu_roofline_us",
                     2 * 1024 ** 3 / PEAK_FLOPS * 1e6,
                     "compute-bound on MXU"))

    # flash attention (XLA chunked path)
    from repro.models.attention import chunked_flash
    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 64)), jnp.bfloat16)
    us = _time(lambda qq: chunked_flash(qq, qq, qq, True, 0, 0.0, 0.125,
                                        256, 256), q)
    csv_rows.append(("flash_1x8x1024x64_xla_us", us, ""))

    # ssd
    from repro.kernels.ssd import ssd
    B, H, S, P, N = 1, 8, 512, 64, 64
    xs = jnp.asarray(rng.standard_normal((B, H, S, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, H, S)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, H), jnp.float32)
    D = jnp.asarray(rng.standard_normal(H), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, 1, S, N)) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, 1, S, N)) * 0.3, jnp.float32)
    us = _time(lambda *t: ssd(*t, chunk=128, backend="xla"),
               xs, dt, A, D, Bm, Cm)
    csv_rows.append(("ssd_1x8x512_xla_us", us, ""))

    # decode attention
    from repro.kernels.decode_attention import decode_attention
    qd = jnp.asarray(rng.standard_normal((4, 8, 128)), jnp.bfloat16)
    kd = jnp.asarray(rng.standard_normal((4, 2, 4096, 128)), jnp.bfloat16)
    us = _time(lambda a, b: decode_attention(a, b, b, backend="xla"),
               qd, kd)
    csv_rows.append(("decode_attn_4x8_kv4096_xla_us", us, ""))
    kv_bytes = 2 * 4 * 2 * 4096 * 128 * 2
    csv_rows.append(("decode_attn_tpu_roofline_us",
                     kv_bytes / HBM_BW * 1e6, "KV-stream bound"))
