"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json):
per (arch × shape × mesh): the three terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS ratio, and collective cross-check against the
iDMA ICI simulator (`dist.collectives`)."""

from __future__ import annotations

import glob
import json
import os

from repro.dist.collectives import allreduce_seconds

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_artifacts():
    arts = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            arts.append((os.path.basename(path), json.load(f)))
    return arts


def run(csv_rows):
    arts = load_artifacts()
    if not arts:
        csv_rows.append(("roofline_artifacts_missing", 0,
                         "run: python -m repro.launch.dryrun --all"))
        return
    for name, d in arts:
        rl = d["roofline"]
        tag = name.replace(".json", "")
        mf = d.get("model_flops_global", 0.0) / max(d["n_devices"], 1)
        ratio = mf / max(rl["flops_per_device"], 1.0)
        csv_rows.append((f"roofline_{tag}_compute_s", rl["compute_s"], ""))
        csv_rows.append((f"roofline_{tag}_memory_s", rl["memory_s"], ""))
        csv_rows.append((f"roofline_{tag}_collective_s",
                         rl["collective_s"], ""))
        csv_rows.append((f"roofline_{tag}_bottleneck",
                         {"compute": 0, "memory": 1,
                          "collective": 2}[rl["bottleneck"]],
                         rl["bottleneck"]))
        csv_rows.append((f"roofline_{tag}_model_over_hlo_flops", ratio, ""))
    # cross-check: one gradient all-reduce through the iDMA ICI model
    csv_rows.append(("ici_allreduce_1GiB_256dev_s",
                     allreduce_seconds(1 << 30, 256),
                     "iDMA transport model over ICI"))
