"""MemPool (§3.4) and Manticore (§3.5, Fig. 11) workload-speedup studies.

Double-buffered iDMA execution vs cores-copy baselines, modeled with the
transport-layer simulator + per-kernel compute intensities:

MemPool: 256 cores, 512-b AXI to L2; baseline cores use 1/16 of the wide
interconnect (paper); iDMA reaches ~99 % utilization.  Kernel time =
max(T_compute, T_dma) double-buffered vs T_compute + T_copy_by_cores.

Manticore: per-cluster tiles; baseline narrow interconnect 48 GB/s,
iDMA wide path 384 GB/s; GEMM/SpMV/SpMM with S/M/L/XL tiles (SuiteSparse
matrices for the sparse kernels).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core import (DescriptorBatch, EngineConfig, MemSystem, Transfer1D,
                        simulate_batch)

# ---------------------------------------------------------------- MemPool

MEMPOOL_BUS = 64           # bytes/cycle (512-b AXI)
CORE_FRACTION = 1 / 16     # paper: cores utilize one sixteenth of the bus
MEMPOOL_L2 = MemSystem("L2", latency=20, outstanding=32)


def _idma_cycles(nbytes: int) -> int:
    cfg = EngineConfig(bus_width=MEMPOOL_BUS, n_outstanding=32,
                       buffer_beats=64, decoupled=True)
    batch = DescriptorBatch.from_transfers([Transfer1D(0, 0, nbytes)])
    r = simulate_batch(batch, cfg, MEMPOOL_L2, MEMPOOL_L2)
    return r.cycles


@dataclass
class Kernel:
    name: str
    bytes_moved: int
    compute_cycles: int          # on the 256 cores, data-resident
    paper_speedup: float


# compute cycles calibrated from kernel arithmetic intensity on 256 cores
MEMPOOL_KERNELS = [
    Kernel("memcpy_512KiB", 512 * 1024, 0, 15.8),
    Kernel("vecadd", 512 * 1024, 600, 15.7),
    Kernel("dot", 512 * 1024, 700, 15.8),
    Kernel("dct", 512 * 1024, 21_000, 7.2),
    Kernel("conv2d", 512 * 1024, 15_500, 9.5),
    Kernel("matmul", 512 * 1024, 330_000, 1.4),
]


def mempool_speedup(k: Kernel) -> float:
    t_dma = _idma_cycles(k.bytes_moved)
    t_cores_copy = k.bytes_moved / (MEMPOOL_BUS * CORE_FRACTION)
    baseline = t_cores_copy + k.compute_cycles
    dbuf = max(t_dma, k.compute_cycles) + min(t_dma, k.compute_cycles) * 0.02
    return baseline / dbuf


# --------------------------------------------------------------- Manticore

NARROW_GBS = 48.0
WIDE_GBS = 384.0
CLUSTER_GFLOPS = 8 * 2 * 1.0          # 8 FPUs x FMA @1 GHz per cluster
N_CLUSTERS = 24                        # per chiplet die


@dataclass
class Tile:
    name: str
    flops: float                      # per tile
    bytes_: float                     # per tile
    paper_range: str


def _gemm_tile(n: int) -> Tile:
    return Tile(f"gemm_{n}", 2 * n ** 3, 3 * n * n * 8, "1.37-1.52x")


# SuiteSparse tiles (n, nnz) from the collection
_SP = {"diag": (2000, 2000), "cz2548": (2548, 12168),
       "bcsstk13": (2003, 83883), "raefsky1": (3242, 293409)}


def _spmv_tile(name: str) -> Tile:
    n, nnz = _SP[name]
    return Tile(f"spmv_{name}", 2 * nnz, (nnz * 12 + n * 16), "5.9-8.4x")


def _spmm_tile(name: str) -> Tile:
    n, nnz = _SP[name]
    k = 32                            # dense rhs columns
    return Tile(f"spmm_{name}", 2 * nnz * k, (nnz * 12 + 2 * n * k * 8),
                "2.9-4.9x")


def manticore_speedup(t: Tile, reuse: float = 1.0,
                      idma_eff: float = 1.0) -> float:
    """Baseline: cores copy in/out SERIALLY around compute over the narrow
    interconnect (paper: 'the cores copying data in and out before and
    after the computation'); iDMA: wide interconnect, double buffered.
    `reuse` — on-chip data reuse factor (caching); `idma_eff` — achieved
    fraction of wide-interconnect peak (small/sparse tiles stay
    latency-bound; paper Fig. 11: approaches 384 GB/s only at XL)."""
    comp = t.flops / (CLUSTER_GFLOPS * N_CLUSTERS) / 1e9      # seconds
    base_mem = t.bytes_ / (NARROW_GBS * 1e9) / reuse
    idma_mem = t.bytes_ / (WIDE_GBS * 1e9 * idma_eff) / reuse
    baseline = comp + base_mem                # serial copy-compute-copy
    idma = max(comp, idma_mem)                # double-buffered overlap
    return baseline / idma


# reuse / efficiency calibration per tile (see docstring; Fig. 11).
# GEMM reuse falls with tile size (relative copy overhead of the serial
# baseline shrinks); SpMM reuse grows with density (caching pays off).
_GEMM_REUSE = {24: 10.8, 32: 7.0, 48: 4.6, 64: 2.9}
_SP_EFF = {"diag": 0.74, "cz2548": 0.80, "bcsstk13": 0.95,
           "raefsky1": 1.0}
_SPMM_REUSE = {"diag": 1.0, "cz2548": 6.0, "bcsstk13": 1.3,
               "raefsky1": 1.2}


def run(csv_rows):
    for k in MEMPOOL_KERNELS:
        s = mempool_speedup(k)
        csv_rows.append((f"mempool_{k.name}_speedup", s,
                         f"paper={k.paper_speedup}x"))
    util = 1.0 - (_idma_cycles(512 * 1024) - 512 * 1024 / MEMPOOL_BUS) / \
        _idma_cycles(512 * 1024)
    csv_rows.append(("mempool_512KiB_bus_utilization", util, "paper=0.99"))

    for n in (24, 32, 48, 64):
        t = _gemm_tile(n)
        csv_rows.append((f"manticore_{t.name}_speedup",
                         manticore_speedup(t, reuse=_GEMM_REUSE[n]),
                         t.paper_range))
    for name in _SP:
        t = _spmv_tile(name)
        csv_rows.append((f"manticore_{t.name}_speedup",
                         manticore_speedup(t, idma_eff=_SP_EFF[name]),
                         t.paper_range))
        t2 = _spmm_tile(name)
        csv_rows.append((f"manticore_{t2.name}_speedup",
                         manticore_speedup(t2, reuse=_SPMM_REUSE[name],
                                           idma_eff=_SP_EFF[name]),
                         t2.paper_range))
