"""Fig. 8 reproduction: bus utilization vs transfer length, iDMA vs a
non-decoupled store-and-forward engine (AXI DMA v7.1 class), Cheshire
configuration (64-b bus, SPM endpoint).

Runs on the structure-of-arrays descriptor plane: `fragmented_copy`
builds one `DescriptorBatch` per sweep cell and `simulate_batch` walks
the burst recurrences over arrays."""

from __future__ import annotations

from repro.core import (DescriptorBatch, MemSystem, cheshire_idma_config,
                        fragmented_copy, simulate_batch,
                        xilinx_baseline_config)

LENGTHS = [8, 16, 32, 64, 128, 256, 512, 1024, 4096]
SPM = MemSystem("SPM", latency=10, outstanding=8)


def run(csv_rows):
    idma = cheshire_idma_config()
    xil = xilinx_baseline_config()
    for length in LENGTHS:
        ri = fragmented_copy(64 * 1024, length, idma, SPM, SPM)
        rx = fragmented_copy(64 * 1024, length, xil, SPM, SPM)
        ratio = ri.utilization / max(rx.utilization, 1e-9)
        csv_rows.append((f"fig8_util_idma_{length}B", ri.utilization,
                         f"xilinx={rx.utilization:.3f},ratio={ratio:.2f}"))
    # headline claim: ~6x at 64 B
    ri = fragmented_copy(64 * 1024, 64, idma, SPM, SPM)
    rx = fragmented_copy(64 * 1024, 64, xil, SPM, SPM)
    csv_rows.append(("fig8_64B_speedup_vs_xilinx",
                     ri.utilization / rx.utilization, "paper=~6x"))
    # PULP §3.1: 8 KiB transfer cycles
    from repro.core import Protocol, Transfer1D, pulp_idma_config
    from repro.core.simulator import PULP_L2, PULP_TCDM
    r = simulate_batch(
        DescriptorBatch.from_transfers(
            [Transfer1D(0, 0, 8192, Protocol.OBI, Protocol.AXI4)]),
        pulp_idma_config(), PULP_TCDM, PULP_L2)
    csv_rows.append(("pulp_8KiB_cycles", r.cycles,
                     "paper=1107,ideal=1024"))
