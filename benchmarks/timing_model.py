"""Fig. 13 reproduction: maximum clock frequency vs configuration."""

from __future__ import annotations

from repro.core import analytics as A
from repro.core.analytics import PortConfig
from repro.core.descriptor import Protocol

CONFIGS = [
    ("obi", [PortConfig(Protocol.OBI)]),
    ("axi_lite", [PortConfig(Protocol.AXI_LITE)]),
    ("axi", [PortConfig(Protocol.AXI4)]),
    ("tilelink", [PortConfig(Protocol.TILELINK)]),
    ("axi_obi", [PortConfig(Protocol.AXI4), PortConfig(Protocol.OBI)]),
    ("all_protocols", [PortConfig(p) for p in
                       (Protocol.AXI4, Protocol.AXI_LITE, Protocol.OBI,
                        Protocol.TILELINK, Protocol.AXI_STREAM)]),
]


def run(csv_rows):
    for name, ports in CONFIGS:
        for dw in (32, 64, 128, 256, 512):
            f = A.max_frequency_ghz(ports, dw=dw)
            csv_rows.append((f"fig13_{name}_dw{dw}_GHz", f, ""))
    csv_rows.append(("fig13_manticore_512b_GHz",
                     A.max_frequency_ghz(A.base_axi_ports(), aw=48, dw=512,
                                         nax=32),
                     "paper=>1GHz @12nm"))
