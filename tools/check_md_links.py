"""Markdown link check: every local link/anchor target in *.md exists.

No network, no dependencies — external (http/https/mailto) links are
syntax-checked only.  Exits non-zero listing broken local links, so CI
catches a doc pointing at a moved module or a deleted file.

    python tools/check_md_links.py [root]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules",
             ".claude"}


def md_files(root: Path):
    for p in root.rglob("*.md"):
        if not SKIP_DIRS.intersection(p.relative_to(root).parts):
            yield p


def check(root: Path) -> int:
    broken = []
    for md in md_files(root):
        text = md.read_text(encoding="utf-8")
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                broken.append(f"{md.relative_to(root)}: ({target})")
    if broken:
        print("broken local markdown links:")
        for b in broken:
            print(f"  {b}")
        return 1
    print(f"markdown links OK ({sum(1 for _ in md_files(root))} files)")
    return 0


if __name__ == "__main__":
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(".")
    sys.exit(check(root.resolve()))
