"""Generate the EXPERIMENTS.md roofline/dry-run tables from artifacts."""

import glob
import json
import os
import sys


def load(d):
    out = {}
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        out[os.path.basename(p).replace(".json", "")] = json.load(open(p))
    return out


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}T"
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b/1e3:.0f}K"


def table(arts, mesh_tag, out):
    rows = []
    for name, d in sorted(arts.items()):
        if not name.endswith(mesh_tag):
            continue
        rl = d["roofline"]
        mf = d.get("model_flops_global", 0) / max(d["n_devices"], 1)
        ratio = mf / max(rl["flops_per_device"], 1)
        step = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        frac = rl["compute_s"] / step if step else 0
        mem = d["memory"]
        rows.append(
            f"| {d['name']} | {rl['compute_s']:.4g} | {rl['memory_s']:.4g} "
            f"| {rl['collective_s']:.4g} | {rl['bottleneck']} "
            f"| {ratio:.2f} | {frac:.2f} "
            f"| {fmt_bytes(mem.get('argument_bytes') or 0)} "
            f"| {fmt_bytes(mem.get('temp_bytes') or 0)} "
            f"| {d['compile_s']:.0f}s |")
    print("| cell | compute_s | memory_s | collective_s | bound "
          "| model/HLO | frac | args/dev | temp/dev | compile |", file=out)
    print("|---|---|---|---|---|---|---|---|---|---|", file=out)
    for r in rows:
        print(r, file=out)


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    arts = load(d)
    print(f"### single-pod (16x16 = 256 chips) — {d}")
    table(arts, "_pod1", sys.stdout)
    print(f"\n### multi-pod (2x16x16 = 512 chips) — {d}")
    table(arts, "_pod2", sys.stdout)
